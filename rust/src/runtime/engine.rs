//! PJRT execution engine: loads AOT HLO-text artifacts and runs them.
//!
//! The pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. Executables are compiled once and cached.
//!
//! Threading: the `xla` crate's `PjRtClient` is `Rc`-based and clones the
//! Rc inside `execute` (output buffers hold client handles), so concurrent
//! use from multiple threads is unsound. `SharedEngine` therefore wraps the
//! whole engine in a `Mutex`; worker threads serialize their PJRT calls and
//! XLA's own intra-op thread pool parallelizes *within* each call. This
//! mirrors a fleet of single-core-ish Lambda workers multiplexed onto one
//! host (see DESIGN.md §3) — per-worker *virtual* time is tracked by the
//! FaaS simulator, not by wall-clock contention here.

use super::manifest::{Manifest, VariantSpec};
use crate::util::error::{anyhow, Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

/// Output of one gradient step.
pub struct GradStepOut {
    pub loss: f32,
    pub grads: Vec<f32>,
}

/// Output of one optimizer application.
pub struct ApplyOut {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
}

pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
    /// cumulative PJRT execute calls (metrics)
    pub n_executions: u64,
}

// SAFETY: Engine is only ever used behind `SharedEngine`'s Mutex; the inner
// Rc refcounts are never touched concurrently. Moving the whole engine
// between threads is fine because all contained pointers target PJRT
// objects that are not thread-affine.
unsafe impl Send for Engine {}

impl Engine {
    pub fn new(manifest: Manifest) -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, executables: HashMap::new(), n_executions: 0 })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile(&mut self, key: String, path: &Path) -> Result<()> {
        if self.executables.contains_key(&key) {
            return Ok(());
        }
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {key}"))?;
        self.executables.insert(key, exe);
        Ok(())
    }

    /// Ensure a variant's executables are compiled (amortizes cold start).
    pub fn warm(&mut self, variant: &str) -> Result<()> {
        let spec = self.manifest.variant(variant)?.clone();
        self.compile(format!("{variant}/grad_step"), &spec.grad_step_path)?;
        self.compile(format!("{variant}/apply_update"), &spec.apply_update_path)?;
        Ok(())
    }

    fn exec(&mut self, key: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(key)
            .ok_or_else(|| anyhow!("executable {key} not compiled — call warm()"))?;
        // IMPORTANT: go through explicit PjRtBuffers + execute_b. The
        // crate's `execute(Literal...)` path leaks its internal
        // host-literal -> device-buffer conversions (~one input-set per
        // call; ~80 MB/step on the `small` variant — measured in
        // EXPERIMENTS.md §Perf L3 iteration 7). Buffers we create have a
        // correct Drop.
        let bufs = args
            .iter()
            .map(|l| self.client.buffer_from_host_literal(None, l))
            .collect::<Result<Vec<_>, _>>()?;
        let result = exe.execute_b::<xla::PjRtBuffer>(&bufs)?;
        self.n_executions += 1;
        let lit = result[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: output is always a tuple.
        Ok(lit.to_tuple()?)
    }

    /// One gradient step: (flat_params, tokens) -> (loss, flat_grads).
    pub fn grad_step(
        &mut self,
        variant: &str,
        params: &[f32],
        tokens: &[i32],
    ) -> Result<GradStepOut> {
        let spec = self.manifest.variant(variant)?.clone();
        self.check_shapes(&spec, params.len(), Some(tokens.len()))?;
        self.warm(variant)?;
        let p = xla::Literal::vec1(params);
        let t = xla::Literal::vec1(tokens)
            .reshape(&[spec.batch as i64, spec.seq_len as i64 + 1])?;
        let outs = self.exec(&format!("{variant}/grad_step"), &[p, t])?;
        if outs.len() != 2 {
            return Err(anyhow!("grad_step returned {} outputs", outs.len()));
        }
        let loss = outs[0].get_first_element::<f32>()?;
        let grads = outs[1].to_vec::<f32>()?;
        Ok(GradStepOut { loss, grads })
    }

    /// One fused-Adam application over the flat parameter vector.
    /// `lr_t` is the bias-corrected step size (see kernels/adam.py).
    #[allow(clippy::too_many_arguments)]
    pub fn apply_update(
        &mut self,
        variant: &str,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        grads: &[f32],
        lr_t: f32,
    ) -> Result<ApplyOut> {
        let spec = self.manifest.variant(variant)?.clone();
        self.check_shapes(&spec, params.len(), None)?;
        self.warm(variant)?;
        let args = [
            xla::Literal::vec1(params),
            xla::Literal::vec1(m),
            xla::Literal::vec1(v),
            xla::Literal::vec1(grads),
            xla::Literal::vec1(&[lr_t]).reshape(&[1, 1])?,
        ];
        let outs = self.exec(&format!("{variant}/apply_update"), &args)?;
        if outs.len() != 3 {
            return Err(anyhow!("apply_update returned {} outputs", outs.len()));
        }
        Ok(ApplyOut {
            params: outs[0].to_vec::<f32>()?,
            m: outs[1].to_vec::<f32>()?,
            v: outs[2].to_vec::<f32>()?,
        })
    }

    /// XLA-path shard aggregation: mean over the worker axis of
    /// `stacked` (n_workers x shard_len, row-major). Used by the
    /// `--agg xla` ablation; the default hot path is the native SIMD mean
    /// in `sync::aggregate_mean`.
    pub fn shard_mean(&mut self, n_workers: usize, shard_len: usize, stacked: &[f32])
        -> Result<Vec<f32>> {
        if stacked.len() != n_workers * shard_len {
            return Err(anyhow!(
                "shard_mean: {} elements != {n_workers}x{shard_len}", stacked.len()));
        }
        let spec = self
            .manifest
            .aggregators
            .iter()
            .find(|a| a.n_workers == n_workers && a.shard_len == shard_len)
            .ok_or_else(|| anyhow!("no aggregator artifact for w{n_workers} l{shard_len}"))?
            .clone();
        let key = format!("agg/w{n_workers}_l{shard_len}");
        self.compile(key.clone(), &spec.path)?;
        let s = xla::Literal::vec1(stacked)
            .reshape(&[n_workers as i64, shard_len as i64])?;
        let outs = self.exec(&key, &[s])?;
        Ok(outs[0].to_vec::<f32>()?)
    }

    fn check_shapes(
        &self,
        spec: &VariantSpec,
        n_params: usize,
        n_tokens: Option<usize>,
    ) -> Result<()> {
        if n_params != spec.n_params {
            return Err(anyhow!(
                "param vector has {n_params} elements, artifact compiled for {}",
                spec.n_params
            ));
        }
        if let Some(nt) = n_tokens {
            let want = spec.batch * (spec.seq_len + 1);
            if nt != want {
                return Err(anyhow!("token block has {nt} elements, want {want}"));
            }
        }
        Ok(())
    }
}

/// Thread-shareable engine handle (see module docs for the Mutex rationale).
#[derive(Clone)]
pub struct SharedEngine(Arc<Mutex<Engine>>);

impl SharedEngine {
    pub fn new(manifest: Manifest) -> Result<SharedEngine> {
        Ok(SharedEngine(Arc::new(Mutex::new(Engine::new(manifest)?))))
    }

    pub fn with<R>(&self, f: impl FnOnce(&mut Engine) -> R) -> R {
        let mut guard = self.0.lock().expect("engine mutex poisoned");
        f(&mut guard)
    }
}
