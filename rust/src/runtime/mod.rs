//! PJRT runtime: the AOT-artifact loading/execution layer.
//!
//! Python lowers the L2/L1 computation once (`make artifacts`); everything
//! here is pure Rust + the `xla` crate (PJRT C API) — no Python on the
//! training path.

pub mod engine;
pub mod manifest;
pub mod params;

pub use engine::{ApplyOut, Engine, GradStepOut, SharedEngine};
pub use manifest::{AggregatorSpec, Manifest, SmokeRecord, TensorSpec, VariantSpec};
