//! PJRT runtime: the AOT-artifact loading/execution layer.
//!
//! Python lowers the L2/L1 computation once (`make artifacts`); everything
//! here is pure Rust + the `xla` crate (PJRT C API) — no Python on the
//! training path.

#[cfg(feature = "pjrt")]
pub mod engine;
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
pub mod engine;
pub mod manifest;
pub mod params;

pub use engine::{ApplyOut, Engine, GradStepOut, SharedEngine};
pub use manifest::{AggregatorSpec, Manifest, SmokeRecord, TensorSpec, VariantSpec};
