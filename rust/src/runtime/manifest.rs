//! Parsed view of `artifacts/manifest.json` (written by python/compile/aot.py).

use crate::util::json::Json;
use crate::util::error::{anyhow, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One tensor in the flat parameter layout.
#[derive(Clone, Debug, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub init: String, // "normal:<std>" | "zeros" | "ones"
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT-compiled model variant.
#[derive(Clone, Debug)]
pub struct VariantSpec {
    pub name: String,
    pub n_params: usize,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub grad_step_path: PathBuf,
    pub apply_update_path: PathBuf,
    pub param_spec: Vec<TensorSpec>,
}

/// One AOT-compiled shard-mean aggregator.
#[derive(Clone, Debug)]
pub struct AggregatorSpec {
    pub n_workers: usize,
    pub shard_len: usize,
    pub path: PathBuf,
}

/// Ground-truth numbers from the python side for cross-language checks.
#[derive(Clone, Debug, Default)]
pub struct SmokeRecord {
    pub variant: String,
    pub seed: u64,
    pub expected_loss: f64,
    pub grads_l2: f64,
    pub params_l2_after_update: f64,
    pub params_head: Vec<f64>,
    pub tokens_head: Vec<i64>,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub variants: BTreeMap<String, VariantSpec>,
    pub aggregators: Vec<AggregatorSpec>,
    pub smoke: SmokeRecord,
}

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("manifest: missing key '{key}'"))
}

fn req_usize(j: &Json, key: &str) -> Result<usize> {
    req(j, key)?.as_usize().ok_or_else(|| anyhow!("manifest: '{key}' not a number"))
}

fn req_str(j: &Json, key: &str) -> Result<String> {
    Ok(req(j, key)?
        .as_str()
        .ok_or_else(|| anyhow!("manifest: '{key}' not a string"))?
        .to_string())
}

impl Manifest {
    /// Load `<root>/manifest.json`. `root` is typically `artifacts/`.
    pub fn load(root: impl AsRef<Path>) -> Result<Manifest> {
        let root = root.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;

        let mut variants = BTreeMap::new();
        for (name, v) in req(&j, "variants")?
            .as_obj()
            .ok_or_else(|| anyhow!("variants not an object"))?
        {
            let param_spec = req(v, "param_spec")?
                .as_arr()
                .ok_or_else(|| anyhow!("param_spec not an array"))?
                .iter()
                .map(|e| {
                    Ok(TensorSpec {
                        name: req_str(e, "name")?,
                        shape: req(e, "shape")?
                            .as_arr()
                            .ok_or_else(|| anyhow!("shape not an array"))?
                            .iter()
                            .map(|d| d.as_usize().unwrap_or(0))
                            .collect(),
                        init: req_str(e, "init")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let spec = VariantSpec {
                name: name.clone(),
                n_params: req_usize(v, "n_params")?,
                vocab: req_usize(v, "vocab")?,
                d_model: req_usize(v, "d_model")?,
                n_layers: req_usize(v, "n_layers")?,
                n_heads: req_usize(v, "n_heads")?,
                d_ff: req_usize(v, "d_ff")?,
                seq_len: req_usize(v, "seq_len")?,
                batch: req_usize(v, "batch")?,
                grad_step_path: root.join(req_str(v, "grad_step")?),
                apply_update_path: root.join(req_str(v, "apply_update")?),
                param_spec,
            };
            let spec_total: usize = spec.param_spec.iter().map(|t| t.numel()).sum();
            if spec_total != spec.n_params {
                return Err(anyhow!(
                    "variant {name}: param_spec totals {spec_total} != n_params {}",
                    spec.n_params
                ));
            }
            variants.insert(name.clone(), spec);
        }

        let mut aggregators = Vec::new();
        for (_k, a) in req(&j, "aggregators")?
            .as_obj()
            .ok_or_else(|| anyhow!("aggregators not an object"))?
        {
            aggregators.push(AggregatorSpec {
                n_workers: req_usize(a, "n_workers")?,
                shard_len: req_usize(a, "shard_len")?,
                path: root.join(req_str(a, "path")?),
            });
        }

        let s = req(&j, "smoke")?;
        let smoke = SmokeRecord {
            variant: req_str(s, "variant")?,
            seed: req_usize(s, "seed")? as u64,
            expected_loss: req(s, "expected_loss")?.as_f64().unwrap_or(f64::NAN),
            grads_l2: req(s, "grads_l2")?.as_f64().unwrap_or(f64::NAN),
            params_l2_after_update: req(s, "params_l2_after_update")?
                .as_f64()
                .unwrap_or(f64::NAN),
            params_head: req(s, "params_head")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_f64())
                .collect(),
            tokens_head: req(s, "tokens_head")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|x| x.as_f64().map(|f| f as i64))
                .collect(),
        };

        Ok(Manifest { root, variants, aggregators, smoke })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantSpec> {
        self.variants
            .get(name)
            .ok_or_else(|| anyhow!("unknown model variant '{name}' (have: {:?})",
                self.variants.keys().collect::<Vec<_>>()))
    }

    /// Default artifacts root: `$SMLT_ARTIFACTS` or `<crate>/artifacts`.
    pub fn default_root() -> PathBuf {
        std::env::var("SMLT_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| {
                Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_real_manifest_when_present() {
        let root = Manifest::default_root();
        if !root.join("manifest.json").exists() {
            return; // `make artifacts` not run yet
        }
        let m = Manifest::load(&root).unwrap();
        assert!(m.variants.contains_key("tiny"));
        let tiny = m.variant("tiny").unwrap();
        assert_eq!(tiny.param_spec[0].name, "tok_emb");
        assert!(tiny.grad_step_path.exists());
        assert!(tiny.apply_update_path.exists());
        assert!(!m.aggregators.is_empty());
        assert_eq!(m.smoke.variant, "tiny");
        assert!(m.smoke.expected_loss > 0.0);
    }

    #[test]
    fn rejects_missing_root() {
        assert!(Manifest::load("/nonexistent/path").is_err());
    }
}
