//! SMLT's Bayesian optimizer: GP posterior + Expected Improvement (§3.2).
//!
//! EI(C_i) = (y_min - mu(C_i)) * Phi(z) + sigma(C_i) * phi(z),
//! z = (y_min - mu) / sigma — the minimization form of the paper's
//! formula (they phrase it with y_max as "best so far"; we minimize cost
//! or time). The search iterates until expected improvement falls below a
//! threshold or the max iteration budget is hit, exactly as described.

use super::search::{Config, ConfigSpace};
use super::{Gp, Objective};
use crate::util::rng::Pcg;
use crate::util::stats::{norm_cdf, norm_pdf};

#[derive(Clone, Debug)]
pub struct BoParams {
    /// random warm-up evaluations before the GP drives the search
    pub n_init: u32,
    /// max total profiling evaluations
    pub max_iters: u32,
    /// stop when best EI / |best y| drops below this
    pub ei_tolerance: f64,
    /// candidate points scored per acquisition round
    pub n_candidates: u32,
    pub seed: u64,
}

impl Default for BoParams {
    fn default() -> Self {
        BoParams { n_init: 4, max_iters: 18, ei_tolerance: 1e-3, n_candidates: 512, seed: 7 }
    }
}

/// Result of one optimization run.
#[derive(Clone, Debug)]
pub struct BoResult {
    pub best: Config,
    pub best_value: f64,
    pub evaluations: u32,
    /// total profiling time spent (s) — the Fig 4 "overhead" metric
    pub profiling_s: f64,
    /// (config, value) trace in evaluation order
    pub trace: Vec<(Config, f64)>,
}

/// Everything that varies between invocations of one configured
/// [`BayesOpt`]: the GP prior (with optional per-point noise inflation)
/// and an optional probe-budget override. The search *strategy* (warm-up
/// size, candidate pool, EI tolerance, seed) stays in [`BoParams`]; the
/// spec carries the per-call *inputs*. `SearchSpec::default()` is a cold,
/// prior-free search — bit-identical to the old `run()`.
#[derive(Clone, Debug, Default)]
pub struct SearchSpec {
    /// `(config, objective value)` pairs measured by *earlier* runs (the
    /// cross-job [`PosteriorBank`](crate::warm::PosteriorBank), rescored
    /// under the caller's goal). Prior points inform the posterior but
    /// never count as evaluations or incumbents: the best-observed value
    /// comes from live probes only, so a stale prior can misdirect early
    /// acquisition but cannot fabricate a result. With a non-empty prior
    /// the random warm-up shrinks to a single probe — the banked surface
    /// replaces it. Prior configs outside the current (possibly
    /// quota-shrunken) space are ignored.
    pub prior: Vec<(Config, f64)>,
    /// Per-point **noise-inflation factors** (≥ 1), parallel to `prior`:
    /// the point enters the GP with its noise variance multiplied by the
    /// factor, so a stale banked measurement widens the posterior instead
    /// of anchoring it (see
    /// [`staleness_inflation`](crate::warm::staleness_inflation)).
    /// Missing entries default to 1.0 (full trust); factors below 1 are
    /// clamped up to 1 (a prior is never trusted *more* than a live
    /// probe).
    pub weights: Vec<f64>,
    /// Cap on total live probes for *this* call, overriding
    /// [`BoParams::max_iters`] when a non-empty prior was accepted — the
    /// "second same-family job re-profiles on a small refresh budget"
    /// pattern, without rebuilding the optimizer. Ignored for cold
    /// searches: a refresh budget only makes sense against a warm
    /// posterior.
    pub refresh_budget: Option<u32>,
}

impl SearchSpec {
    /// Prior-free cold search (same as `SearchSpec::default()`).
    pub fn fresh() -> SearchSpec {
        SearchSpec::default()
    }

    /// Seed the GP from fully-trusted `(config, value)` pairs.
    pub fn from_prior(prior: &[(Config, f64)]) -> SearchSpec {
        SearchSpec { prior: prior.to_vec(), ..SearchSpec::default() }
    }

    /// Seed the GP from `(config, value, noise-inflation)` triples.
    pub fn from_weighted_prior(prior: &[(Config, f64, f64)]) -> SearchSpec {
        SearchSpec {
            prior: prior.iter().map(|&(c, y, _)| (c, y)).collect(),
            weights: prior.iter().map(|&(_, _, f)| f).collect(),
            ..SearchSpec::default()
        }
    }
}

pub struct BayesOpt {
    pub params: BoParams,
    pub space: ConfigSpace,
}

impl BayesOpt {
    pub fn new(space: ConfigSpace, params: BoParams) -> Self {
        BayesOpt { params, space }
    }

    /// Expected improvement at posterior (mu, sigma) given incumbent y_min.
    pub fn expected_improvement(y_min: f64, mu: f64, sigma: f64) -> f64 {
        if sigma <= 1e-12 {
            return (y_min - mu).max(0.0);
        }
        let z = (y_min - mu) / sigma;
        (y_min - mu) * norm_cdf(z) + sigma * norm_pdf(z)
    }

    #[deprecated(since = "0.7.0", note = "use BayesOpt::search with SearchSpec::default()")]
    pub fn run(&self, obj: &mut dyn Objective) -> BoResult {
        self.search(obj, &SearchSpec::default())
    }

    #[deprecated(since = "0.7.0", note = "use BayesOpt::search with SearchSpec::from_prior")]
    pub fn run_with_prior(&self, obj: &mut dyn Objective, prior: &[(Config, f64)]) -> BoResult {
        self.search(obj, &SearchSpec::from_prior(prior))
    }

    #[deprecated(
        since = "0.7.0",
        note = "use BayesOpt::search with SearchSpec::from_weighted_prior"
    )]
    pub fn run_with_weighted_prior(
        &self,
        obj: &mut dyn Objective,
        prior: &[(Config, f64, f64)],
    ) -> BoResult {
        self.search(obj, &SearchSpec::from_weighted_prior(prior))
    }

    /// Run the optimization loop against `obj` under `spec`. An empty
    /// default spec is the plain cold search; a spec with a prior seeds
    /// the GP posterior before any live probe (see [`SearchSpec`] for the
    /// exact semantics of each field).
    pub fn search(&self, obj: &mut dyn Objective, spec: &SearchSpec) -> BoResult {
        let mut rng = Pcg::new(self.params.seed);
        let mut gp = Gp::default();
        let mut trace: Vec<(Config, f64)> = Vec::new();
        let mut profiling_s = 0.0;
        let mut best = (Config { workers: 0, mem_mb: 0 }, f64::INFINITY);

        // Cost/time objectives span orders of magnitude across the config
        // space (memory-pressure cliffs, n^2 comm terms); fitting the GP
        // in log space keeps the low-cost region resolvable. argmin is
        // invariant under the monotone transform.
        let warp = |y: f64| (y.max(1e-12)).ln();
        let mut prior_n = 0u32;
        for (i, &(c, y)) in spec.prior.iter().enumerate() {
            if !self.space.contains(c) {
                continue;
            }
            // inflation factor f ≥ 1 → extra (f−1)·noise on the diagonal;
            // f = 1 adds exactly 0.0, keeping the unweighted path
            // bit-identical
            let inflate = spec.weights.get(i).copied().unwrap_or(1.0);
            let extra = (inflate.max(1.0) - 1.0) * gp.noise_var;
            gp.observe_noisy(self.space.normalize(c).to_vec(), warp(y), extra);
            prior_n += 1;
        }
        // a refresh budget only applies against an accepted warm prior
        let max_iters = match spec.refresh_budget {
            Some(b) if prior_n > 0 => b,
            _ => self.params.max_iters,
        };
        let mut evaluate =
            |c: Config, gp: &mut Gp, trace: &mut Vec<(Config, f64)>, prof: &mut f64,
             best: &mut (Config, f64)| {
                let y = obj.eval(c);
                *prof += obj.eval_cost_s(c);
                gp.observe(self.space.normalize(c).to_vec(), warp(y));
                trace.push((c, y));
                if y < best.1 {
                    *best = (c, y);
                }
            };

        // warm-up: random configurations ("randomly chosen configurations"
        // per §3.2); a warm posterior replaces all but one of them
        let n_init = if prior_n > 0 { self.params.n_init.min(1) } else { self.params.n_init };
        for _ in 0..n_init.min(max_iters) {
            let c = self.space.sample(&mut rng);
            evaluate(c, &mut gp, &mut trace, &mut profiling_s, &mut best);
        }

        // acquisition loop (EI computed in the warped space)
        while (trace.len() as u32) < max_iters {
            let y_min_w = warp(best.1);
            let mut best_cand: Option<(Config, f64)> = None;
            // candidate pool: global random samples + local perturbations
            // of the incumbent (helps when the optimum sits in a corner of
            // the space, e.g. tight-deadline feasible regions)
            let mut candidates = Vec::with_capacity(self.params.n_candidates as usize + 16);
            for _ in 0..self.params.n_candidates {
                candidates.push(self.space.sample(&mut rng));
            }
            for _ in 0..16 {
                let dw = (rng.below(9) as i64 - 4) * self.space.worker_step as i64;
                let dm = (rng.below(9) as i64 - 4) * self.space.mem_step_mb as i64;
                candidates.push(self.space.clamp(Config {
                    workers: (best.0.workers as i64 + dw).max(1) as u32,
                    mem_mb: (best.0.mem_mb as i64 + dm).max(1) as u32,
                }));
            }
            for c in candidates {
                if trace.iter().any(|(tc, _)| tc == &c) {
                    continue; // already profiled
                }
                let (mu, sigma) = gp.predict(&self.space.normalize(c));
                let ei = Self::expected_improvement(y_min_w, mu, sigma);
                if best_cand.map(|(_, b)| ei > b).unwrap_or(true) {
                    best_cand = Some((c, ei));
                }
            }
            let Some((next, ei)) = best_cand else { break };
            // log-space EI tolerance: ei_tolerance in relative terms
            if ei < self.params.ei_tolerance {
                break; // expected improvement too small (§3.2 stop rule)
            }
            evaluate(next, &mut gp, &mut trace, &mut profiling_s, &mut best);
        }

        BoResult {
            best: best.0,
            best_value: best.1,
            evaluations: trace.len() as u32,
            profiling_s,
            trace,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Smooth synthetic cost surface with a unique interior optimum.
    struct Bowl {
        evals: u32,
    }

    impl Objective for Bowl {
        fn eval(&mut self, c: Config) -> f64 {
            self.evals += 1;
            let w = c.workers as f64 / 100.0;
            let m = c.mem_mb as f64 / 10_240.0;
            // optimum near workers=60, mem=4096
            10.0 * (w - 0.6).powi(2) + 8.0 * (m - 0.4).powi(2) + 1.0
        }
        fn eval_cost_s(&self, _c: Config) -> f64 {
            30.0
        }
    }

    #[test]
    fn ei_formula_sane() {
        // far-better posterior mean => EI ~ improvement
        let ei = BayesOpt::expected_improvement(10.0, 5.0, 0.1);
        assert!((ei - 5.0).abs() < 0.05);
        // no uncertainty, worse mean => zero
        assert_eq!(BayesOpt::expected_improvement(10.0, 12.0, 0.0), 0.0);
        // uncertainty adds exploration value even at equal mean
        assert!(BayesOpt::expected_improvement(10.0, 10.0, 2.0) > 0.5);
    }

    #[test]
    fn finds_near_optimum_with_few_evals() {
        let space = ConfigSpace::default();
        let mut obj = Bowl { evals: 0 };
        let bo = BayesOpt::new(space, BoParams::default());
        let res = bo.search(&mut obj, &SearchSpec::default());
        assert!(res.evaluations <= 18);
        assert!(
            res.best_value < 1.6,
            "found {:?} = {}",
            res.best,
            res.best_value
        );
        // vastly fewer evaluations than the grid (~6.4k points)
        assert!(res.evaluations < 40);
        assert!((res.profiling_s - res.evaluations as f64 * 30.0).abs() < 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let space = ConfigSpace::default();
        let bo = BayesOpt::new(space, BoParams::default());
        let r1 = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::default());
        let r2 = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::fresh());
        assert_eq!(r1.best, r2.best);
        assert_eq!(r1.trace.len(), r2.trace.len());
    }

    #[test]
    fn empty_prior_is_bit_identical_to_fresh_search() {
        let space = ConfigSpace::default();
        let bo = BayesOpt::new(space, BoParams::default());
        let a = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::default());
        let b = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::from_prior(&[]));
        assert_eq!(a.best, b.best);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.profiling_s.to_bits(), b.profiling_s.to_bits());
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_wrappers_are_bit_identical_to_search() {
        let space = ConfigSpace::default();
        let bo = BayesOpt::new(space, BoParams::default());
        let mut donor = Bowl { evals: 0 };
        let c = Config { workers: 60, mem_mb: 4096 };
        let prior = vec![(c, donor.eval(c))];
        let weighted = vec![(c, prior[0].1, 2.0)];

        let a = bo.run(&mut Bowl { evals: 0 });
        let b = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::default());
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.profiling_s.to_bits(), b.profiling_s.to_bits());

        let a = bo.run_with_prior(&mut Bowl { evals: 0 }, &prior);
        let b = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::from_prior(&prior));
        assert_eq!(a.trace, b.trace);

        let a = bo.run_with_weighted_prior(&mut Bowl { evals: 0 }, &weighted);
        let b = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::from_weighted_prior(&weighted));
        assert_eq!(a.trace, b.trace);
    }

    #[test]
    fn warm_prior_still_finds_the_optimum_on_a_refresh_budget() {
        // the driver pairs a banked prior with a small refresh budget
        // (like its re-optimization branch); the informed GP must land
        // near the optimum anyway, with the full warm-up skipped
        let space = ConfigSpace::default();
        let mut donor = Bowl { evals: 0 };
        let prior: Vec<(Config, f64)> = [
            (10u32, 512u32),
            (40, 2048),
            (60, 4096),
            (80, 6144),
            (120, 8192),
            (180, 9216),
        ]
        .iter()
        .map(|&(w, m)| {
            let c = Config { workers: w, mem_mb: m };
            (c, donor.eval(c))
        })
        .collect();
        let bo = BayesOpt::new(space, BoParams { n_init: 4, ..Default::default() });
        let spec = SearchSpec { refresh_budget: Some(6), ..SearchSpec::from_prior(&prior) };
        let warm = bo.search(&mut Bowl { evals: 0 }, &spec);
        assert!(
            warm.evaluations <= 6,
            "refresh budget respected: {}",
            warm.evaluations
        );
        assert!(
            warm.best_value < 1.6,
            "warm run still finds the optimum: {:?} = {}",
            warm.best,
            warm.best_value
        );
        // a non-empty prior collapses the random warm-up to one probe, so
        // the acquisition loop ran informed from the second evaluation on
        assert!(warm.evaluations >= 1);
    }

    #[test]
    fn refresh_budget_is_ignored_without_an_accepted_prior() {
        let space = ConfigSpace { max_workers: 50, ..Default::default() };
        let bo = BayesOpt::new(space, BoParams::default());
        // no prior at all, and a prior entirely outside the shrunken
        // space: both leave the full max_iters budget in force
        let cold = SearchSpec { refresh_budget: Some(2), ..SearchSpec::default() };
        let rejected = SearchSpec {
            refresh_budget: Some(2),
            ..SearchSpec::from_prior(&[(Config { workers: 120, mem_mb: 4096 }, 1.0)])
        };
        let a = bo.search(&mut Bowl { evals: 0 }, &cold);
        let b = bo.search(&mut Bowl { evals: 0 }, &rejected);
        assert!(a.evaluations > 2, "cold search keeps its full budget");
        assert!(b.evaluations > 2, "rejected prior keeps the full budget");
    }

    #[test]
    fn unit_weight_prior_is_bit_identical_to_plain_prior() {
        let space = ConfigSpace::default();
        let bo = BayesOpt::new(
            space,
            BoParams { n_init: 1, max_iters: 6, ..Default::default() },
        );
        let mut donor = Bowl { evals: 0 };
        let prior: Vec<(Config, f64)> = [(20u32, 1024u32), (60, 4096), (140, 8192)]
            .iter()
            .map(|&(w, m)| {
                let c = Config { workers: w, mem_mb: m };
                (c, donor.eval(c))
            })
            .collect();
        let weighted: Vec<(Config, f64, f64)> =
            prior.iter().map(|&(c, y)| (c, y, 1.0)).collect();
        let a = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::from_prior(&prior));
        let b = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::from_weighted_prior(&weighted));
        assert_eq!(a.best, b.best);
        assert_eq!(a.trace, b.trace);
        assert_eq!(a.profiling_s.to_bits(), b.profiling_s.to_bits());
        // sub-unit factors clamp up to full trust, never below
        let clamped: Vec<(Config, f64, f64)> =
            prior.iter().map(|&(c, y)| (c, y, 0.25)).collect();
        let c = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::from_weighted_prior(&clamped));
        assert_eq!(a.trace, c.trace);
    }

    #[test]
    fn inflated_prior_still_respects_budget_and_finds_optimum() {
        // a *stale* prior (heavy noise inflation) must neither panic nor
        // blow the refresh budget; the search still lands near the bowl's
        // bottom because live probes override the widened prior
        let space = ConfigSpace::default();
        let mut donor = Bowl { evals: 0 };
        let prior: Vec<(Config, f64, f64)> = [
            (10u32, 512u32),
            (40, 2048),
            (60, 4096),
            (120, 8192),
        ]
        .iter()
        .map(|&(w, m)| {
            let c = Config { workers: w, mem_mb: m };
            (c, donor.eval(c), 1024.0)
        })
        .collect();
        let bo = BayesOpt::new(
            space,
            BoParams { n_init: 2, max_iters: 8, ..Default::default() },
        );
        let res = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::from_weighted_prior(&prior));
        assert!(res.evaluations <= 8);
        assert!(res.best_value.is_finite());
        assert!(res.best_value < 5.0, "found {:?} = {}", res.best, res.best_value);
    }

    #[test]
    fn out_of_space_prior_points_are_ignored() {
        let space = ConfigSpace {
            max_workers: 50,
            ..Default::default()
        };
        let bo = BayesOpt::new(space, BoParams::default());
        // a prior measured under a roomier quota: workers=120 is outside
        // the shrunken space and must not panic or poison the GP
        let prior = vec![(Config { workers: 120, mem_mb: 4096 }, 1.0)];
        let res = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::from_prior(&prior));
        assert!(res.best.workers <= 50);
        assert!(res.best_value.is_finite());
    }

    #[test]
    fn trace_never_repeats_configs() {
        let bo = BayesOpt::new(ConfigSpace::default(), BoParams::default());
        let res = bo.search(&mut Bowl { evals: 0 }, &SearchSpec::default());
        for i in 0..res.trace.len() {
            for j in i + 1..res.trace.len() {
                assert_ne!(res.trace[i].0, res.trace[j].0);
            }
        }
    }
}
