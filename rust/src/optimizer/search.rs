//! Configuration space + exhaustive/random search baselines.

use crate::util::rng::Pcg;

/// One deployment configuration c_i = ⟨workers, memory⟩ (§3.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct Config {
    pub workers: u32,
    pub mem_mb: u32,
}

/// Discrete 2-D search space. The paper searches memory 128 MB – 10 GB at
/// 1 MB granularity and workers per model size; we keep the same bounds
/// with a configurable memory step (the GP interpolates between steps, so
/// a coarser profiling grid loses nothing).
#[derive(Clone, Debug)]
pub struct ConfigSpace {
    pub min_workers: u32,
    pub max_workers: u32,
    pub worker_step: u32,
    pub min_mem_mb: u32,
    pub max_mem_mb: u32,
    pub mem_step_mb: u32,
}

impl Default for ConfigSpace {
    fn default() -> Self {
        ConfigSpace {
            min_workers: 2,
            max_workers: 200,
            worker_step: 2,
            min_mem_mb: 128,
            max_mem_mb: 10_240,
            mem_step_mb: 128,
        }
    }
}

impl ConfigSpace {
    /// Whether `c` lies inside the space's bounds (grid alignment not
    /// required — the GP interpolates off-grid points fine). Used to
    /// filter banked prior observations deposited under a differently
    /// bounded space before normalizing them.
    pub fn contains(&self, c: Config) -> bool {
        (self.min_workers..=self.max_workers).contains(&c.workers)
            && (self.min_mem_mb..=self.max_mem_mb).contains(&c.mem_mb)
    }

    pub fn clamp(&self, c: Config) -> Config {
        Config {
            workers: c.workers.clamp(self.min_workers, self.max_workers),
            mem_mb: c.mem_mb.clamp(self.min_mem_mb, self.max_mem_mb),
        }
    }

    pub fn all(&self) -> Vec<Config> {
        let mut out = Vec::new();
        let mut w = self.min_workers;
        while w <= self.max_workers {
            let mut m = self.min_mem_mb;
            while m <= self.max_mem_mb {
                out.push(Config { workers: w, mem_mb: m });
                m += self.mem_step_mb;
            }
            w += self.worker_step;
        }
        out
    }

    pub fn sample(&self, rng: &mut Pcg) -> Config {
        let nw = (self.max_workers - self.min_workers) / self.worker_step + 1;
        let nm = (self.max_mem_mb - self.min_mem_mb) / self.mem_step_mb + 1;
        Config {
            workers: self.min_workers + self.worker_step * rng.below(nw as u64) as u32,
            mem_mb: self.min_mem_mb + self.mem_step_mb * rng.below(nm as u64) as u32,
        }
    }

    /// Memory grid for a coordinate-descent resize pass: the incumbent
    /// first (so strict-improvement comparisons keep it on ties), then
    /// every on-grid size. Used by `resize_search` to sweep `mem_mb`
    /// while holding workers fixed, mirroring `sync_search`'s
    /// policy sweep.
    pub fn mem_candidates(&self, incumbent: u32) -> Vec<u32> {
        let mut out = vec![incumbent];
        let mut m = self.min_mem_mb;
        while m <= self.max_mem_mb {
            if m != incumbent {
                out.push(m);
            }
            m += self.mem_step_mb;
        }
        out
    }

    /// Normalize to [0,1]^2 for GP length-scale stability.
    pub fn normalize(&self, c: Config) -> [f64; 2] {
        [
            (c.workers - self.min_workers) as f64
                / (self.max_workers - self.min_workers).max(1) as f64,
            (c.mem_mb - self.min_mem_mb) as f64
                / (self.max_mem_mb - self.min_mem_mb).max(1) as f64,
        ]
    }
}

/// Exhaustive search: the "prohibitively expensive" strawman of §3.2.
pub struct GridSearch;

impl GridSearch {
    /// Evaluate everything; returns (best config, best value, evals used).
    pub fn run(obj: &mut dyn super::Objective, space: &ConfigSpace) -> (Config, f64, u32) {
        let mut best = (Config { workers: 0, mem_mb: 0 }, f64::INFINITY);
        let mut evals = 0;
        for c in space.all() {
            let y = obj.eval(c);
            evals += 1;
            if y < best.1 {
                best = (c, y);
            }
        }
        (best.0, best.1, evals)
    }
}

/// Random search with a fixed budget.
pub struct RandomSearch;

impl RandomSearch {
    pub fn run(
        obj: &mut dyn super::Objective,
        space: &ConfigSpace,
        budget: u32,
        seed: u64,
    ) -> (Config, f64, u32) {
        let mut rng = Pcg::new(seed);
        let mut best = (Config { workers: 0, mem_mb: 0 }, f64::INFINITY);
        for _ in 0..budget {
            let c = space.sample(&mut rng);
            let y = obj.eval(c);
            if y < best.1 {
                best = (c, y);
            }
        }
        (best.0, best.1, budget)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn space_enumeration_and_bounds() {
        let s = ConfigSpace {
            min_workers: 2,
            max_workers: 6,
            worker_step: 2,
            min_mem_mb: 128,
            max_mem_mb: 384,
            mem_step_mb: 128,
        };
        let all = s.all();
        assert_eq!(all.len(), 3 * 3);
        assert!(all.iter().all(|c| c.workers >= 2 && c.workers <= 6));
    }

    #[test]
    fn normalize_unit_square() {
        let s = ConfigSpace::default();
        let lo = s.normalize(Config { workers: s.min_workers, mem_mb: s.min_mem_mb });
        let hi = s.normalize(Config { workers: s.max_workers, mem_mb: s.max_mem_mb });
        assert_eq!(lo, [0.0, 0.0]);
        assert_eq!(hi, [1.0, 1.0]);
    }

    #[test]
    fn mem_candidates_incumbent_first_no_duplicates() {
        let s = ConfigSpace {
            min_workers: 2,
            max_workers: 6,
            worker_step: 2,
            min_mem_mb: 128,
            max_mem_mb: 512,
            mem_step_mb: 128,
        };
        // on-grid incumbent: appears exactly once, in front
        let cands = s.mem_candidates(256);
        assert_eq!(cands, vec![256, 128, 384, 512]);
        // off-grid incumbent (clamped space drift): still listed first,
        // full grid follows
        let cands = s.mem_candidates(200);
        assert_eq!(cands, vec![200, 128, 256, 384, 512]);
    }

    #[test]
    fn sample_respects_grid() {
        let s = ConfigSpace::default();
        let mut rng = Pcg::new(1);
        for _ in 0..200 {
            let c = s.sample(&mut rng);
            assert_eq!((c.workers - s.min_workers) % s.worker_step, 0);
            assert_eq!((c.mem_mb - s.min_mem_mb) % s.mem_step_mb, 0);
            assert!(c.workers <= s.max_workers && c.mem_mb <= s.max_mem_mb);
        }
    }
}
