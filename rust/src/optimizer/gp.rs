//! Gaussian-process regression (squared-exponential kernel, Cholesky
//! solve) — the posterior model behind SMLT's Bayesian optimizer (§3.2).
//!
//! Inputs live in [0,1]^d (the ConfigSpace normalizes); targets are
//! standardized internally. Posterior updates are incremental-friendly:
//! refitting at n ≤ a few dozen profiling points is O(n^3) with a tiny
//! constant, far below one profiling run's cost (§Perf L3 notes).

/// Squared-exponential GP with fixed hyperparameters.
#[derive(Clone, Debug)]
pub struct Gp {
    pub length_scale: f64,
    pub signal_var: f64,
    pub noise_var: f64,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    /// per-observation extra noise variance added on top of `noise_var`
    /// (0 for live measurements; staleness-discounted priors inflate it)
    extra_noise: Vec<f64>,
    y_mean: f64,
    y_std: f64,
    /// Cholesky factor of K + noise*I (lower triangular, row-major)
    chol: Vec<f64>,
    /// alpha = (K + noise I)^-1 (y - mean)
    alpha: Vec<f64>,
}

impl Default for Gp {
    fn default() -> Self {
        Gp::new(0.25, 1.0, 1e-4)
    }
}

impl Gp {
    pub fn new(length_scale: f64, signal_var: f64, noise_var: f64) -> Gp {
        Gp {
            length_scale,
            signal_var,
            noise_var,
            xs: Vec::new(),
            ys: Vec::new(),
            extra_noise: Vec::new(),
            y_mean: 0.0,
            y_std: 1.0,
            chol: Vec::new(),
            alpha: Vec::new(),
        }
    }

    pub fn n(&self) -> usize {
        self.xs.len()
    }

    fn kernel(&self, a: &[f64], b: &[f64]) -> f64 {
        let d2: f64 = a.iter().zip(b).map(|(x, y)| (x - y).powi(2)).sum();
        self.signal_var * (-0.5 * d2 / (self.length_scale * self.length_scale)).exp()
    }

    /// Add one observation and refit.
    pub fn observe(&mut self, x: Vec<f64>, y: f64) {
        self.observe_noisy(x, y, 0.0);
    }

    /// Add one observation carrying `extra_noise_var` of additional noise
    /// variance on its kernel diagonal, and refit. Inflated noise makes
    /// the point *advisory*: the posterior mean is pulled toward it less,
    /// and the posterior variance near it stays wider — how
    /// staleness-discounted priors from the
    /// [`PosteriorBank`](crate::warm::PosteriorBank) enter the GP.
    /// `extra_noise_var = 0` is exactly [`observe`](Self::observe).
    pub fn observe_noisy(&mut self, x: Vec<f64>, y: f64, extra_noise_var: f64) {
        self.xs.push(x);
        self.ys.push(y);
        self.extra_noise.push(extra_noise_var.max(0.0));
        self.refit();
    }

    fn refit(&mut self) {
        let n = self.xs.len();
        self.y_mean = self.ys.iter().sum::<f64>() / n as f64;
        let var = self
            .ys
            .iter()
            .map(|y| (y - self.y_mean).powi(2))
            .sum::<f64>()
            / n as f64;
        self.y_std = var.sqrt().max(1e-9);

        // K + noise I
        let mut k = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = self.kernel(&self.xs[i], &self.xs[j]);
                k[i * n + j] = v;
                k[j * n + i] = v;
            }
            k[i * n + i] += self.noise_var + self.extra_noise[i];
        }
        self.chol = cholesky(&k, n).expect("GP kernel matrix not PD");
        // alpha = K^-1 y_standardized
        let ystd: Vec<f64> = self
            .ys
            .iter()
            .map(|y| (y - self.y_mean) / self.y_std)
            .collect();
        self.alpha = chol_solve(&self.chol, n, &ystd);
    }

    /// Posterior (mean, std) at `x` in the original target units.
    pub fn predict(&self, x: &[f64]) -> (f64, f64) {
        let n = self.xs.len();
        if n == 0 {
            return (0.0, self.signal_var.sqrt());
        }
        let kstar: Vec<f64> = self.xs.iter().map(|xi| self.kernel(xi, x)).collect();
        let mean_std = kstar.iter().zip(&self.alpha).map(|(a, b)| a * b).sum::<f64>();
        // v = L^-1 k*
        let v = forward_sub(&self.chol, n, &kstar);
        let var = (self.kernel(x, x) - v.iter().map(|z| z * z).sum::<f64>()).max(1e-12);
        (
            mean_std * self.y_std + self.y_mean,
            var.sqrt() * self.y_std,
        )
    }

    /// Current best (lowest) observed value, original units.
    pub fn best_observed(&self) -> Option<(usize, f64)> {
        self.ys
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, y)| (i, *y))
    }

    pub fn observed_x(&self, i: usize) -> &[f64] {
        &self.xs[i]
    }
}

/// Dense lower Cholesky of an n x n SPD matrix (row-major).
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0.0; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve L z = b (forward substitution).
fn forward_sub(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let mut z = vec![0.0; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * z[k];
        }
        z[i] = s / l[i * n + i];
    }
    z
}

/// Solve (L L^T) x = b.
fn chol_solve(l: &[f64], n: usize, b: &[f64]) -> Vec<f64> {
    let z = forward_sub(l, n, b);
    let mut x = vec![0.0; n];
    for i in (0..n).rev() {
        let mut s = z[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cholesky_known_matrix() {
        // A = [[4,2],[2,3]] => L = [[2,0],[1,sqrt(2)]]
        let l = cholesky(&[4.0, 2.0, 2.0, 3.0], 2).unwrap();
        assert!((l[0] - 2.0).abs() < 1e-12);
        assert!((l[2] - 1.0).abs() < 1e-12);
        assert!((l[3] - 2f64.sqrt()).abs() < 1e-12);
        assert!(cholesky(&[1.0, 2.0, 2.0, 1.0], 2).is_none(), "not PD");
    }

    #[test]
    fn solve_roundtrip() {
        let a = [4.0, 2.0, 2.0, 3.0];
        let l = cholesky(&a, 2).unwrap();
        let x = chol_solve(&l, 2, &[1.0, 2.0]);
        // check A x = b
        let b0 = a[0] * x[0] + a[1] * x[1];
        let b1 = a[2] * x[0] + a[3] * x[1];
        assert!((b0 - 1.0).abs() < 1e-10 && (b1 - 2.0).abs() < 1e-10);
    }

    #[test]
    fn gp_interpolates_observations() {
        let mut gp = Gp::new(0.3, 1.0, 1e-6);
        let f = |x: f64| (3.0 * x).sin() + 5.0;
        for i in 0..8 {
            let x = i as f64 / 7.0;
            gp.observe(vec![x], f(x));
        }
        for i in 0..8 {
            let x = i as f64 / 7.0;
            let (m, s) = gp.predict(&[x]);
            assert!((m - f(x)).abs() < 1e-2, "at {x}: {m} vs {}", f(x));
            assert!(s < 0.05);
        }
        // between points: reasonable, higher uncertainty than at points
        let (m, s_mid) = gp.predict(&[0.5 / 7.0 + 0.5 / 7.0]);
        assert!((m - 5.0).abs() < 2.0);
        let (_, s_at) = gp.predict(&[0.0]);
        assert!(s_mid >= s_at * 0.5);
    }

    #[test]
    fn gp_uncertainty_grows_away_from_data() {
        let mut gp = Gp::default();
        gp.observe(vec![0.0, 0.0], 1.0);
        gp.observe(vec![0.1, 0.1], 1.2);
        let (_, s_near) = gp.predict(&[0.05, 0.05]);
        let (_, s_far) = gp.predict(&[1.0, 1.0]);
        assert!(s_far > s_near * 2.0, "{s_far} vs {s_near}");
    }

    #[test]
    fn noisy_observations_are_advisory() {
        // same data, one conflicting point: with large extra noise the
        // conflicting point barely moves the posterior; with none it does
        let fit = |extra: f64| {
            let mut gp = Gp::new(0.3, 1.0, 1e-4);
            gp.observe(vec![0.2], 1.0);
            gp.observe(vec![0.8], 1.0);
            gp.observe_noisy(vec![0.5], 5.0, extra);
            let (m, s) = gp.predict(&[0.5]);
            (m, s)
        };
        let (m_trusted, s_trusted) = fit(0.0);
        let (m_stale, s_stale) = fit(100.0);
        // trusted: posterior interpolates the 5.0 point closely
        assert!((m_trusted - 5.0).abs() < 0.5, "trusted mean {m_trusted}");
        // stale: pulled far less toward the conflicting value...
        assert!(
            (m_stale - 5.0).abs() > 2.0 * (m_trusted - 5.0).abs(),
            "stale mean {m_stale} vs trusted {m_trusted}"
        );
        // ...and the posterior stays wider there
        assert!(s_stale > s_trusted, "{s_stale} vs {s_trusted}");
        // zero extra noise is bit-identical to a plain observation
        let mut a = Gp::default();
        a.observe(vec![0.3], 2.0);
        let mut b = Gp::default();
        b.observe_noisy(vec![0.3], 2.0, 0.0);
        let (ma, sa) = a.predict(&[0.6]);
        let (mb, sb) = b.predict(&[0.6]);
        assert_eq!(ma.to_bits(), mb.to_bits());
        assert_eq!(sa.to_bits(), sb.to_bits());
    }

    #[test]
    fn best_observed_tracks_minimum() {
        let mut gp = Gp::default();
        gp.observe(vec![0.1], 5.0);
        gp.observe(vec![0.5], 2.0);
        gp.observe(vec![0.9], 7.0);
        let (i, y) = gp.best_observed().unwrap();
        assert_eq!(i, 1);
        assert_eq!(y, 2.0);
        assert_eq!(gp.observed_x(1), &[0.5]);
    }
}
