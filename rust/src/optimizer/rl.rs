//! Reinforcement-learning optimizer baseline (Fig 4; Siren's approach).
//!
//! Tabular Q-learning over the discretized configuration grid with
//! move/stay actions. It reaches accuracy comparable to the Bayesian
//! optimizer but needs episodes of environment interaction — i.e. ~3x the
//! profiling evaluations — which is exactly the overhead gap the paper
//! reports and why SMLT chose BO.

use super::search::{Config, ConfigSpace};
use super::Objective;
use crate::util::rng::Pcg;
use std::collections::HashMap;

#[derive(Clone, Debug)]
pub struct RlParams {
    pub episodes: u32,
    pub steps_per_episode: u32,
    pub alpha: f64,
    pub gamma: f64,
    pub epsilon: f64,
    pub seed: u64,
}

impl Default for RlParams {
    fn default() -> Self {
        RlParams { episodes: 9, steps_per_episode: 12, alpha: 0.5, gamma: 0.9, epsilon: 0.3, seed: 11 }
    }
}

#[derive(Clone, Debug)]
pub struct RlResult {
    pub best: Config,
    pub best_value: f64,
    pub evaluations: u32,
    pub profiling_s: f64,
}

const ACTIONS: [(i32, i32); 5] = [(0, 0), (1, 0), (-1, 0), (0, 1), (0, -1)];

pub struct QLearner {
    pub params: RlParams,
    pub space: ConfigSpace,
}

impl QLearner {
    pub fn new(space: ConfigSpace, params: RlParams) -> Self {
        QLearner { params, space }
    }

    fn apply(&self, c: Config, a: (i32, i32)) -> Config {
        self.space.clamp(Config {
            workers: (c.workers as i64 + a.0 as i64 * self.space.worker_step as i64 * 4)
                .max(self.space.min_workers as i64) as u32,
            mem_mb: (c.mem_mb as i64 + a.1 as i64 * self.space.mem_step_mb as i64 * 4)
                .max(self.space.min_mem_mb as i64) as u32,
        })
    }

    pub fn run(&self, obj: &mut dyn Objective) -> RlResult {
        let mut rng = Pcg::new(self.params.seed);
        let mut q: HashMap<(Config, usize), f64> = HashMap::new();
        let mut cache: HashMap<Config, f64> = HashMap::new();
        let mut evals = 0u32;
        let mut profiling_s = 0.0;
        let mut best = (Config { workers: 0, mem_mb: 0 }, f64::INFINITY);

        for _ep in 0..self.params.episodes {
            let mut state = self.space.sample(&mut rng);
            for _step in 0..self.params.steps_per_episode {
                // epsilon-greedy
                let a_idx = if rng.next_f64() < self.params.epsilon {
                    rng.below(ACTIONS.len() as u64) as usize
                } else {
                    (0..ACTIONS.len())
                        .max_by(|&a, &b| {
                            let qa = q.get(&(state, a)).copied().unwrap_or(0.0);
                            let qb = q.get(&(state, b)).copied().unwrap_or(0.0);
                            qa.partial_cmp(&qb).unwrap()
                        })
                        .unwrap()
                };
                let next = self.apply(state, ACTIONS[a_idx]);
                // every *new* state visit costs a profiling run — this is
                // the structural overhead vs BO
                let y = *cache.entry(next).or_insert_with(|| {
                    evals += 1;
                    profiling_s += obj.eval_cost_s(next);
                    obj.eval(next)
                });
                if y < best.1 {
                    best = (next, y);
                }
                let reward = -y;
                let max_next = (0..ACTIONS.len())
                    .map(|a| q.get(&(next, a)).copied().unwrap_or(0.0))
                    .fold(f64::NEG_INFINITY, f64::max);
                let entry = q.entry((state, a_idx)).or_insert(0.0);
                *entry += self.params.alpha
                    * (reward + self.params.gamma * max_next - *entry);
                state = next;
            }
        }
        RlResult { best: best.0, best_value: best.1, evaluations: evals, profiling_s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optimizer::{BayesOpt, BoParams, SearchSpec};

    struct Bowl;
    impl Objective for Bowl {
        fn eval(&mut self, c: Config) -> f64 {
            let w = c.workers as f64 / 100.0;
            let m = c.mem_mb as f64 / 10_240.0;
            10.0 * (w - 0.6).powi(2) + 8.0 * (m - 0.4).powi(2) + 1.0
        }
        fn eval_cost_s(&self, _c: Config) -> f64 {
            30.0
        }
    }

    #[test]
    fn rl_finds_decent_config() {
        let rl = QLearner::new(ConfigSpace::default(), RlParams::default());
        let res = rl.run(&mut Bowl);
        assert!(res.best_value < 2.5, "{:?} -> {}", res.best, res.best_value);
    }

    #[test]
    fn rl_costs_about_3x_bo_profiling() {
        // the Fig 4 structural result; exact ratio depends on params but
        // RL must be materially more expensive for similar quality
        let bo = BayesOpt::new(ConfigSpace::default(), BoParams::default());
        let bo_res = bo.search(&mut Bowl, &SearchSpec::default());
        let rl = QLearner::new(ConfigSpace::default(), RlParams::default());
        let rl_res = rl.run(&mut Bowl);
        assert!(
            rl_res.profiling_s > 2.0 * bo_res.profiling_s,
            "rl {} vs bo {}",
            rl_res.profiling_s,
            bo_res.profiling_s
        );
        // quality within the same ballpark
        assert!(rl_res.best_value < bo_res.best_value * 2.0 + 0.5);
    }

    #[test]
    fn deterministic() {
        let rl = QLearner::new(ConfigSpace::default(), RlParams::default());
        let a = rl.run(&mut Bowl);
        let b = rl.run(&mut Bowl);
        assert_eq!(a.best, b.best);
        assert_eq!(a.evaluations, b.evaluations);
    }
}
