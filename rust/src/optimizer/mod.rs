//! Deployment-configuration optimizers (§3.2, Fig 4).
//!
//! SMLT's optimizer is a Gaussian-process Bayesian optimizer with the
//! Expected-Improvement acquisition over the 2-D space
//! ⟨number of workers, memory per worker⟩. The RL (tabular Q-learning)
//! optimizer reproduces the paper's Fig 4 comparison — same accuracy at
//! ~3x the profiling overhead — and grid/random searches serve as
//! ablation baselines.

pub mod bayesian;
pub mod gp;
pub mod rl;
pub mod search;

pub use bayesian::{BayesOpt, BoParams, BoResult, SearchSpec};
pub use gp::Gp;
pub use search::{Config, ConfigSpace, GridSearch, RandomSearch};

/// A black-box objective over deployment configurations. Implementations
/// wrap either the perf-model simulator (benches) or live profiling runs
/// (the resource manager during training).
pub trait Objective {
    /// Observed objective value (lower is better, e.g. $ or seconds,
    /// possibly penalty-augmented for constraint violations).
    fn eval(&mut self, cfg: Config) -> f64;
    /// Cost of one profiling evaluation (seconds of profiling time);
    /// used for the Fig 4 overhead comparison.
    fn eval_cost_s(&self, cfg: Config) -> f64;
}
