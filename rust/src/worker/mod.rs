//! Serverless worker (§4.2, Table 1 ②): data iterator, minibatch buffer,
//! trainer, hierarchical aggregator — the real-mode implementation that
//! actually executes the AOT grad-step through PJRT and moves gradient
//! bytes through the in-process parameter store.

pub mod data;
pub mod runner;
pub mod trainer;

pub use data::{DataIterator, MinibatchBuffer};
pub use runner::{run_worker_fleet, FleetConfig, FleetResult, InvocationBudget};
pub use trainer::Trainer;
