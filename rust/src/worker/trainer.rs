//! Trainer (§4.2 ②c): runs the AOT-compiled training code over one
//! minibatch and applies the aggregated update — thin, typed wrapper
//! around the PJRT engine for one model variant.

use crate::runtime::{SharedEngine, VariantSpec};
use crate::util::error::Result;

/// Adam hyperparameters matching python/compile/kernels/adam.py.
const BETA1: f64 = 0.9;
const BETA2: f64 = 0.999;

/// One worker's training state for a model variant.
pub struct Trainer {
    engine: SharedEngine,
    pub spec: VariantSpec,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub lr: f64,
    /// Adam timestep (bias correction); equals applied updates
    pub t: u64,
}

impl Trainer {
    pub fn new(engine: SharedEngine, spec: VariantSpec, params: Vec<f32>, lr: f64) -> Trainer {
        let n = spec.n_params;
        assert_eq!(params.len(), n);
        Trainer { engine, spec, params, m: vec![0.0; n], v: vec![0.0; n], lr, t: 0 }
    }

    /// Restore optimizer state (checkpoint resume).
    pub fn restore(&mut self, params: Vec<f32>, m: Vec<f32>, v: Vec<f32>, t: u64) {
        self.params = params;
        self.m = m;
        self.v = v;
        self.t = t;
    }

    /// Forward+backward on `tokens`; returns (loss, gradients).
    pub fn grad_step(&self, tokens: &[i32]) -> Result<(f32, Vec<f32>)> {
        let name = self.spec.name.clone();
        let out = self
            .engine
            .with(|e| e.grad_step(&name, &self.params, tokens))?;
        Ok((out.loss, out.grads))
    }

    /// Apply (already-aggregated) gradients with fused Adam.
    pub fn apply(&mut self, grads: &[f32]) -> Result<()> {
        self.t += 1;
        let lr_t = self.lr * (1.0 - BETA2.powi(self.t as i32)).sqrt()
            / (1.0 - BETA1.powi(self.t as i32));
        let name = self.spec.name.clone();
        let out = self.engine.with(|e| {
            e.apply_update(&name, &self.params, &self.m, &self.v, grads, lr_t as f32)
        })?;
        self.params = out.params;
        self.m = out.m;
        self.v = out.v;
        Ok(())
    }
}
