//! Data iterator + minibatch buffer (§4.2 ②a/②b).
//!
//! The paper's data iterator fetches the worker's shard of the training
//! data from the object store each epoch and tracks which samples were
//! processed so a restarted worker resumes mid-epoch. Our object store
//! holds a deterministic synthetic corpus (DESIGN.md §3 substitutions):
//! the Markov generator *is* the shard — fetching = generating, which
//! preserves the resume semantics exactly (the cursor is the state).

use crate::runtime::params::MarkovCorpus;
use crate::runtime::VariantSpec;

/// Tracks the worker's position in its epoch shard; checkpointable.
pub struct DataIterator {
    corpus: MarkovCorpus,
    spec: VariantSpec,
    worker: u64,
    /// monotone batch counter == training iteration; persisted in the
    /// checkpoint so restarts skip already-processed batches
    pub cursor: u64,
}

impl DataIterator {
    pub fn new(spec: VariantSpec, worker: u64, corpus_seed: u64, cursor: u64) -> Self {
        // 8% noise: learnable structure with irreducible entropy
        let corpus = MarkovCorpus::new(spec.vocab, corpus_seed, 8);
        DataIterator { corpus, spec, worker, cursor }
    }

    /// Produce the next (batch, seq_len+1) token block and advance.
    pub fn next_batch(&mut self) -> Vec<i32> {
        let b = self.corpus.batch(&self.spec, self.worker, self.cursor);
        self.cursor += 1;
        b
    }

    /// Peek the batch for an arbitrary iteration without advancing
    /// (used by the minibatch buffer's prefetch).
    pub fn batch_at(&self, cursor: u64) -> Vec<i32> {
        self.corpus.batch(&self.spec, self.worker, cursor)
    }
}

/// One-deep prefetch buffer (§4.2 ②b): keeps the next minibatch staged in
/// memory while the trainer runs the current one.
pub struct MinibatchBuffer {
    staged: Option<(u64, Vec<i32>)>,
}

impl Default for MinibatchBuffer {
    fn default() -> Self {
        Self::new()
    }
}

impl MinibatchBuffer {
    pub fn new() -> Self {
        MinibatchBuffer { staged: None }
    }

    /// Take the batch for `it.cursor`, from the stage if present, and
    /// restage the following one.
    pub fn take(&mut self, it: &mut DataIterator) -> Vec<i32> {
        let want = it.cursor;
        let batch = match self.staged.take() {
            Some((c, b)) if c == want => {
                it.cursor += 1;
                b
            }
            _ => it.next_batch(),
        };
        self.staged = Some((it.cursor, it.batch_at(it.cursor)));
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TensorSpec;

    fn spec() -> VariantSpec {
        VariantSpec {
            name: "t".into(),
            n_params: 1,
            vocab: 64,
            d_model: 4,
            n_layers: 1,
            n_heads: 1,
            d_ff: 4,
            seq_len: 8,
            batch: 2,
            grad_step_path: "/dev/null".into(),
            apply_update_path: "/dev/null".into(),
            param_spec: vec![TensorSpec { name: "x".into(), shape: vec![1], init: "zeros".into() }],
        }
    }

    #[test]
    fn iterator_is_deterministic_and_resumable() {
        let mut a = DataIterator::new(spec(), 3, 42, 0);
        let b0 = a.next_batch();
        let b1 = a.next_batch();
        // a restarted worker resuming at cursor=1 sees exactly b1
        let mut resumed = DataIterator::new(spec(), 3, 42, 1);
        assert_eq!(resumed.next_batch(), b1);
        assert_ne!(b0, b1);
    }

    #[test]
    fn workers_see_different_data() {
        let mut w0 = DataIterator::new(spec(), 0, 42, 0);
        let mut w1 = DataIterator::new(spec(), 1, 42, 0);
        assert_ne!(w0.next_batch(), w1.next_batch());
    }

    #[test]
    fn buffer_preserves_order() {
        let mut plain = DataIterator::new(spec(), 0, 7, 0);
        let expect: Vec<_> = (0..5).map(|_| plain.next_batch()).collect();

        let mut it = DataIterator::new(spec(), 0, 7, 0);
        let mut buf = MinibatchBuffer::new();
        let got: Vec<_> = (0..5).map(|_| buf.take(&mut it)).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn tokens_within_vocab() {
        let mut it = DataIterator::new(spec(), 0, 1, 0);
        for _ in 0..10 {
            let b = it.next_batch();
            assert_eq!(b.len(), 2 * 9);
            assert!(b.iter().all(|&t| t >= 0 && t < 64));
        }
    }
}
