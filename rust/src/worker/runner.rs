//! Real-mode worker fleet: N worker threads training the AOT model with
//! hierarchical gradient synchronization, under serverless lifecycle rules
//! (invocation duration budget → checkpoint → restart) enforced by the
//! task scheduler. This is the engine room of the e2e example.
//!
//! Each "function invocation" is a bounded span of iterations (standing in
//! for the 15-minute Lambda cap, scaled down so tests exercise restarts);
//! a worker whose budget expires checkpoints and is re-invoked, resuming
//! from the stored cursor — exactly the paper's §4.1 protocol.

use super::data::{DataIterator, MinibatchBuffer};
use super::trainer::Trainer;
use crate::runtime::{params, SharedEngine};
use crate::scheduler::checkpoint::{Checkpoint, CheckpointStore};
use crate::storage::ParamStore;
use crate::sync::HierarchicalSync;
use crate::util::error::Result;
use std::sync::mpsc;
use std::sync::{Arc, Barrier};

/// Invocation budget: how many iterations one "function execution" may
/// run before the platform's duration cap forces a restart.
#[derive(Clone, Copy, Debug)]
pub struct InvocationBudget {
    pub iters_per_invocation: u64,
}

#[derive(Clone, Debug)]
pub struct FleetConfig {
    pub variant: String,
    pub n_workers: usize,
    pub total_iters: u64,
    pub lr: f64,
    pub seed: u64,
    pub budget: InvocationBudget,
    /// checkpoint every k iterations (worker 0 writes)
    pub ckpt_every: u64,
}

#[derive(Debug)]
pub struct FleetResult {
    /// (iter, mean loss across workers)
    pub losses: Vec<(u64, f32)>,
    pub restarts: u64,
    pub final_params_l2: f64,
    pub store_counters: crate::storage::kv::Counters,
}

/// One worker invocation: runs [start, end) iterations, returns per-iter
/// losses. Mirrors a single serverless function execution.
#[allow(clippy::too_many_arguments)]
fn invocation(
    engine: &SharedEngine,
    store: &ParamStore,
    ckpts: &CheckpointStore,
    cfg: &FleetConfig,
    worker: usize,
    start: u64,
    end: u64,
    barrier: &Barrier,
) -> Result<Vec<(u64, f32)>> {
    let spec = engine.with(|e| e.manifest().variant(&cfg.variant).cloned())?;

    // (re)initialize — a stateless function must rebuild everything; the
    // checkpoint supplies params/optimizer/data-cursor for resumes
    let mut trainer = match ckpts.load("job") {
        Some(c) if c.iter >= start && start > 0 => {
            let mut t = Trainer::new(
                engine.clone(),
                spec.clone(),
                c.params.clone(),
                cfg.lr,
            );
            t.restore(c.params, c.opt_m, c.opt_v, c.iter);
            t
        }
        _ => Trainer::new(
            engine.clone(),
            spec.clone(),
            params::init_params(&spec, cfg.seed),
            cfg.lr,
        ),
    };
    // data iterator resumes at the invocation's first iteration
    let mut data = DataIterator::new(spec.clone(), worker as u64, cfg.seed ^ 0xC0FFEE, start);
    let mut buffer = MinibatchBuffer::new();
    let sync = HierarchicalSync::new(store.clone(), cfg.n_workers, worker);

    let mut losses = Vec::new();
    for iter in start..end {
        let tokens = buffer.take(&mut data);
        let (loss, grads) = trainer.grad_step(&tokens)?;
        let avg = sync.sync(iter, &grads)?;
        trainer.apply(&avg)?;
        losses.push((iter, loss));
        if worker == 0 && (iter + 1) % cfg.ckpt_every == 0 {
            ckpts.save(
                "job",
                Checkpoint {
                    iter: iter + 1,
                    params: trainer.params.clone(),
                    opt_m: trainer.m.clone(),
                    opt_v: trainer.v.clone(),
                    data_cursor: iter + 1,
                },
            );
        }
    }
    // all workers finish the invocation span before anyone restarts, so
    // the checkpoint the next invocation reads is complete
    barrier.wait();
    if worker == 0 {
        ckpts.save(
            "job",
            Checkpoint {
                iter: end,
                params: trainer.params.clone(),
                opt_m: trainer.m.clone(),
                opt_v: trainer.v.clone(),
                data_cursor: end,
            },
        );
    }
    barrier.wait();
    Ok(losses)
}

/// Train `total_iters` with a fleet of worker threads under invocation
/// budgets. Returns the merged loss curve and lifecycle statistics.
pub fn run_worker_fleet(engine: SharedEngine, cfg: FleetConfig) -> Result<FleetResult> {
    let store = ParamStore::new();
    let ckpts = CheckpointStore::new();
    let mut restarts = 0u64;
    let mut all_losses: Vec<Vec<(u64, f32)>> = vec![Vec::new(); cfg.n_workers];

    // warm the executables once (compile outside the timed region)
    engine.with(|e| e.warm(&cfg.variant))?;

    let mut start = 0u64;
    while start < cfg.total_iters {
        let end = (start + cfg.budget.iters_per_invocation).min(cfg.total_iters);
        if start > 0 {
            restarts += cfg.n_workers as u64; // every worker re-invoked
        }
        let barrier = Arc::new(Barrier::new(cfg.n_workers));
        let (tx, rx) = mpsc::channel();
        std::thread::scope(|scope| {
            for w in 0..cfg.n_workers {
                let engine = engine.clone();
                let store = store.clone();
                let ckpts = ckpts.clone();
                let cfg = cfg.clone();
                let barrier = barrier.clone();
                let tx = tx.clone();
                scope.spawn(move || {
                    let r = invocation(&engine, &store, &ckpts, &cfg, w, start, end, &barrier);
                    tx.send((w, r)).unwrap();
                });
            }
        });
        drop(tx);
        for (w, r) in rx {
            all_losses[w].extend(r?);
        }
        start = end;
    }

    // mean loss across workers per iteration
    let mut merged: std::collections::BTreeMap<u64, (f32, u32)> = Default::default();
    for wl in &all_losses {
        for (i, l) in wl {
            let e = merged.entry(*i).or_insert((0.0, 0));
            e.0 += l;
            e.1 += 1;
        }
    }
    let losses: Vec<(u64, f32)> = merged
        .into_iter()
        .map(|(i, (s, c))| (i, s / c as f32))
        .collect();

    let ckpt = ckpts.load("job").expect("final checkpoint");
    let l2 = ckpt.params.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
    Ok(FleetResult {
        losses,
        restarts,
        final_params_l2: l2,
        store_counters: store.counters(),
    })
}
