//! # SMLT — Serverless Machine Learning Training (paper reproduction)
//!
//! A serverless framework for scalable and adaptive ML design and training
//! (Ali et al., CS.DC 2022), rebuilt as a three-layer Rust + JAX + Pallas
//! stack: the Rust coordinator here is Layer 3; the model and kernels are
//! AOT-compiled from Python (Layers 2/1) and executed through PJRT.
//!
//! Top-level map (see DESIGN.md for the full inventory):
//! - [`runtime`] — PJRT engine: loads `artifacts/*.hlo.txt`, runs
//!   grad-step / optimizer-update / aggregation executables.
//! - [`simclock`] — discrete-event simulation core (virtual time).
//! - [`faas`] — serverless-platform substrate (Lambda-like semantics).
//! - [`storage`] — hybrid storage: object store + parameter store.
//! - [`sync`] — model-synchronization schemes (hierarchical ScatterReduce
//!   and the baselines' centralized variants).
//! - [`perfmodel`] — calibrated per-iteration time model for the paper's
//!   five benchmark models.
//! - [`pipeline`] — FuncPipe-style pipelined model parallelism: stage /
//!   micro-batch specs, the fill-drain schedule and its bubble factor,
//!   per-stage memory feasibility under the per-function cap, and
//!   storage-mediated activation passing on the shared storage path.
//! - [`costmodel`] — cloud pricing (Lambda / S3 / ECS / EC2).
//! - [`optimizer`] — Gaussian-process Bayesian optimizer + RL baseline.
//! - [`scheduler`] — task scheduler: monitoring, checkpoint/restart,
//!   duration-limit rotation, re-optimization triggers.
//! - [`worker`] — serverless worker: data iterator, minibatch buffer,
//!   trainer, hierarchical aggregator.
//! - [`coordinator`] — end client: artifact/resource managers, workloads
//!   (static / dynamic batching / online learning / NAS), and the
//!   reentrant per-job simulation driver (`JobDriver`).
//! - [`cluster`] — multi-tenant fleet layer: job arrival processes
//!   (batch / Poisson / diurnal / online-learning / trace), shared
//!   account concurrency pool
//!   with per-tenant quotas, pluggable slot arbitration (goal-class
//!   priority, weighted fair sharing, class-aware fair sharing, DRF —
//!   each with a configurable starvation bound), capacity traces that
//!   step the account limit mid-run (spot-capacity shocks with lease
//!   reclamation), preemption, and quota-aware re-optimization.
//! - [`warm`] — warm-start layer: fleet-wide warm-container pool (TTL
//!   eviction, keep-alive billing, warm-vs-cold init distributions,
//!   optional exact-Lambda memory-keyed matching), forecast-driven
//!   prewarming from the declared schedule (oracle) or from learned
//!   online EWMA/Holt arrival estimates, and the cross-job
//!   profiling-posterior bank (with age-based staleness discounting)
//!   that seeds repeat jobs' Bayesian searches.
//! - [`baselines`] — Siren, Cirrus, LambdaML, MLCD, IaaS comparators.
//! - [`metrics`] — run recorders, CSV emission, per-tenant
//!   fairness / shock-degradation roll-ups, and the per-job
//!   time/cost attribution pass over recorded traces.
//! - [`trace`] — virtual-time tracing layer: typed span/instant events
//!   from the driver, fleet kernel, warm pool, and pipeline paths
//!   (off by default, strict no-op when disabled), with a Chrome
//!   trace-event / Perfetto JSON exporter and validator.
//! - [`util`] — PRNG, JSON, CLI, stats, error plumbing
//!   (offline-registry substitutes).

pub mod baselines;
pub mod cluster;
pub mod coordinator;
pub mod costmodel;
pub mod faas;
pub mod metrics;
pub mod optimizer;
pub mod perfmodel;
pub mod pipeline;
pub mod runtime;
pub mod scheduler;
pub mod simclock;
pub mod storage;
pub mod sync;
pub mod trace;
pub mod util;
pub mod warm;
pub mod worker;
