//! Cloud cost model: Lambda GB-seconds, S3 requests, ECS container-hours,
//! EC2 VM-hours (us-east-1 list prices, 2022-era, matching the paper).
//!
//! Every simulated deployment accumulates a [`CostLedger`]; Figs 3, 9, 10,
//! 11 and the 3x headline cost claim are computed from it.

/// Pricing constants (USD). Public so benches can ablate.
#[derive(Clone, Debug)]
pub struct Pricing {
    /// Lambda: $ per GB-second of configured memory
    pub lambda_gb_s: f64,
    /// Lambda: $ per request
    pub lambda_request: f64,
    /// provisioned concurrency: $ per GB-second a container is *kept
    /// warm* (what the warm pool's keep-alive accrues at — roughly a
    /// quarter of the active-duration rate, matching AWS list pricing)
    pub lambda_provisioned_gb_s: f64,
    /// S3: $ per GET / per PUT request
    pub s3_get: f64,
    pub s3_put: f64,
    /// S3 storage $/GB-month (negligible for training runs but modeled)
    pub s3_gb_month: f64,
    /// Fargate/ECS: $ per vCPU-hour and per GB-hour (parameter store)
    pub ecs_vcpu_h: f64,
    pub ecs_gb_h: f64,
    /// EC2 on-demand $/h for the IaaS/MLCD baseline VM (m5.2xlarge-like:
    /// 8 vCPU / 32 GB)
    pub vm_hour: f64,
    pub vm_vcpus: f64,
}

impl Default for Pricing {
    fn default() -> Self {
        Pricing {
            lambda_gb_s: 0.0000166667,
            lambda_request: 0.20 / 1e6,
            lambda_provisioned_gb_s: 0.0000041667,
            s3_get: 0.0004 / 1000.0,
            s3_put: 0.005 / 1000.0,
            s3_gb_month: 0.023,
            ecs_vcpu_h: 0.04048,
            ecs_gb_h: 0.004445,
            vm_hour: 0.384,
            vm_vcpus: 8.0,
        }
    }
}

impl Pricing {
    /// Lambda compute cost for `n` workers x `mem_mb` x `seconds` each.
    pub fn lambda_cost(&self, n: u32, mem_mb: u32, seconds: f64) -> f64 {
        let gb = mem_mb as f64 / 1024.0;
        n as f64 * (gb * seconds * self.lambda_gb_s + self.lambda_request)
    }

    /// Keep-alive cost of `gb_s` GB-seconds of warm (provisioned)
    /// container residency.
    pub fn provisioned_cost(&self, gb_s: f64) -> f64 {
        gb_s * self.lambda_provisioned_gb_s
    }

    /// Parameter-store cost: `containers` Fargate tasks (2 vCPU / 4 GB
    /// each) alive for `seconds`.
    pub fn param_store_cost(&self, containers: u32, seconds: f64) -> f64 {
        let h = seconds / 3600.0;
        containers as f64 * h * (2.0 * self.ecs_vcpu_h + 4.0 * self.ecs_gb_h)
    }

    /// VM cost for `n` instances alive `seconds` (billed per second like
    /// modern EC2, with the hourly list rate).
    pub fn vm_cost(&self, n: u32, seconds: f64) -> f64 {
        n as f64 * seconds / 3600.0 * self.vm_hour
    }
}

/// Accumulated cost of one training run / experiment.
#[derive(Clone, Debug, Default)]
pub struct CostLedger {
    pub lambda_compute: f64,
    pub lambda_requests: u64,
    pub s3_gets: u64,
    pub s3_puts: u64,
    pub param_store: f64,
    pub vm: f64,
    /// profiling-phase share of the above (reported separately in Figs 9-11)
    pub profiling: f64,
}

impl CostLedger {
    pub fn add_lambda(&mut self, p: &Pricing, n: u32, mem_mb: u32, seconds: f64) {
        self.lambda_compute += p.lambda_cost(n, mem_mb, seconds);
        self.lambda_requests += n as u64;
    }

    pub fn add_s3(&mut self, gets: u64, puts: u64) {
        self.s3_gets += gets;
        self.s3_puts += puts;
    }

    pub fn add_param_store(&mut self, p: &Pricing, containers: u32, seconds: f64) {
        self.param_store += p.param_store_cost(containers, seconds);
    }

    pub fn add_vm(&mut self, p: &Pricing, n: u32, seconds: f64) {
        self.vm += p.vm_cost(n, seconds);
    }

    /// Mark everything accumulated so far as profiling overhead.
    pub fn mark_profiling(&mut self, p: &Pricing) {
        self.profiling = self.total(p);
    }

    /// Object-store request line ($): GETs + PUTs priced out. The single
    /// source of truth for the S3 line — `total` and the per-tenant
    /// billing view both go through it.
    pub fn s3_cost(&self, p: &Pricing) -> f64 {
        self.s3_gets as f64 * p.s3_get + self.s3_puts as f64 * p.s3_put
    }

    pub fn total(&self, p: &Pricing) -> f64 {
        self.lambda_compute + self.s3_cost(p) + self.param_store + self.vm
    }

    /// Training-only share (total minus the profiling prefix).
    pub fn training_only(&self, p: &Pricing) -> f64 {
        (self.total(p) - self.profiling).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_pricing_reference_points() {
        let p = Pricing::default();
        // 1 GB for 1 second = $0.0000166667 (+1 request)
        let c = p.lambda_cost(1, 1024, 1.0);
        assert!((c - (0.0000166667 + 0.2e-6)).abs() < 1e-12);
        // scaling: 10 workers at 10 GB for 1 h ~ $6.0
        let c = p.lambda_cost(10, 10_240, 3600.0);
        assert!((c - 6.0).abs() < 0.1, "got {c}");
    }

    #[test]
    fn vm_cheaper_when_fully_utilized_lambda_cheaper_when_idle() {
        let p = Pricing::default();
        // equal raw capacity: 1 VM (8 vCPU) vs 8 Lambdas at 1769 MB (1 vCPU)
        let vm = p.vm_cost(1, 3600.0);
        let lam = p.lambda_cost(8, 1769, 3600.0);
        assert!(vm < lam, "fully-utilized VM should be cheaper: {vm} vs {lam}");
        // ...but a 24 h mostly-idle online workload (5% duty cycle)
        let vm_idle = p.vm_cost(1, 24.0 * 3600.0);
        let lam_burst = p.lambda_cost(8, 1769, 0.05 * 24.0 * 3600.0);
        assert!(lam_burst < vm_idle, "{lam_burst} vs {vm_idle}");
    }

    #[test]
    fn ledger_accumulates_and_separates_profiling() {
        let p = Pricing::default();
        let mut l = CostLedger::default();
        l.add_lambda(&p, 4, 2048, 100.0);
        l.add_s3(1000, 100);
        l.mark_profiling(&p);
        let after_profiling = l.total(&p);
        l.add_lambda(&p, 16, 3072, 500.0);
        l.add_param_store(&p, 2, 500.0);
        assert!(l.total(&p) > after_profiling);
        assert!((l.profiling - after_profiling).abs() < 1e-12);
        assert!(l.training_only(&p) > 0.0);
    }

    #[test]
    fn provisioned_rate_undercuts_active_rate() {
        let p = Pricing::default();
        // keeping a container warm must be cheaper than running it —
        // otherwise the warm pool could never win the cost trade
        assert!(p.lambda_provisioned_gb_s < p.lambda_gb_s);
        assert!((p.provisioned_cost(1000.0) - 1000.0 * p.lambda_provisioned_gb_s).abs() < 1e-15);
        assert_eq!(p.provisioned_cost(0.0), 0.0);
    }

    #[test]
    fn param_store_cost_scales_with_time_and_containers() {
        let p = Pricing::default();
        assert!(p.param_store_cost(2, 3600.0) > p.param_store_cost(1, 3600.0));
        assert!((p.param_store_cost(1, 3600.0) - (2.0 * p.ecs_vcpu_h + 4.0 * p.ecs_gb_h)).abs() < 1e-12);
    }
}
