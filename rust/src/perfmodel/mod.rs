//! Calibrated per-iteration performance model for the paper's benchmarks.
//!
//! The authors profile five models (ResNet-18/50, BERT-Small/Medium,
//! Atari-RL) on AWS Lambda. We reproduce the *profiles* — parameter count,
//! gradient bytes, FLOPs per sample, framework init time, extra per-
//! iteration upload (the RL benchmark ships simulation data) — and compute
//! per-iteration compute time from the FaaS CPU scaling model. The
//! serverless-CPU throughput constant is calibrated against real PJRT
//! runs of our own transformer (see `calibrate` + EXPERIMENTS.md).

use crate::faas::FaasPlatform;

/// Which ML framework a job uses — enters only via init overhead and
/// serialization factor, which is exactly how the paper treats the
/// TF/PyTorch/MXNet axis (§5.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Framework {
    Tensorflow,
    Pytorch,
    Mxnet,
}

impl Framework {
    /// Cold initialization of the framework + model build (s); the paper
    /// cites 4 s for ResNet-18 on Tensorflow.
    pub fn init_base_s(&self) -> f64 {
        match self {
            Framework::Tensorflow => 3.0,
            Framework::Pytorch => 2.0,
            Framework::Mxnet => 2.4,
        }
    }
}

/// Static profile of one benchmark model.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: &'static str,
    pub params: u64,
    /// forward FLOPs for one sample
    pub flops_fwd_per_sample: f64,
    /// bytes of one training sample on the wire / in storage
    pub sample_bytes: u64,
    /// extra bytes uploaded per worker per iteration besides gradients
    /// (e.g. RL simulation trajectories)
    pub extra_upload_bytes: u64,
    /// model-dependent extra init (loading weights etc.), seconds
    pub model_init_s: f64,
}

/// Bytes of activations crossing one pipeline-stage boundary per sample,
/// as a multiple of `sqrt(params)`: for a roughly square layer stack the
/// boundary tensor is one hidden vector per token/pixel, whose width
/// scales with `sqrt(params)` while the parameter count scales with its
/// square. Deliberately a single ablatable constant — fig19 sweeps are
/// insensitive to its exact value because gradient and compute volumes
/// dominate.
pub const ACT_BYTES_PER_SQRT_PARAM: f64 = 32.0;

impl ModelProfile {
    pub fn grad_bytes(&self) -> u64 {
        self.params * 4
    }

    /// Activation bytes one sample pushes across a pipeline-stage cut
    /// (see [`ACT_BYTES_PER_SQRT_PARAM`]). Zero-parameter profiles (none
    /// in-tree) would round up to at least one byte.
    pub fn activation_bytes_per_sample(&self) -> u64 {
        (ACT_BYTES_PER_SQRT_PARAM * (self.params as f64).sqrt()).ceil().max(1.0) as u64
    }

    pub fn resnet18() -> Self {
        ModelProfile {
            name: "ResNet-18",
            params: 11_700_000,
            flops_fwd_per_sample: 1.82e9,
            sample_bytes: 150 * 1024, // 224x224 JPEG-ish
            extra_upload_bytes: 0,
            model_init_s: 1.0,
        }
    }

    pub fn resnet50() -> Self {
        ModelProfile {
            name: "ResNet-50",
            params: 23_500_000,
            flops_fwd_per_sample: 4.1e9,
            sample_bytes: 150 * 1024,
            extra_upload_bytes: 0,
            model_init_s: 2.0,
        }
    }

    pub fn bert_small() -> Self {
        ModelProfile {
            name: "Bert-Small",
            params: 66_000_000,
            // ~2 * params FLOPs per token x 128-token sequences
            flops_fwd_per_sample: 2.0 * 66e6 * 128.0,
            sample_bytes: 2 * 128, // token ids
            extra_upload_bytes: 0,
            model_init_s: 2.5,
        }
    }

    pub fn bert_medium() -> Self {
        ModelProfile {
            name: "Bert-Medium",
            params: 110_000_000,
            flops_fwd_per_sample: 2.0 * 110e6 * 128.0,
            sample_bytes: 2 * 128,
            extra_upload_bytes: 0,
            model_init_s: 3.5,
        }
    }

    /// Atari breakout RL (A2C-style): small model, but every iteration
    /// uploads fresh simulation trajectories — the paper observes its
    /// upload time exceeding ResNet-50's (§5.2).
    pub fn atari_rl() -> Self {
        ModelProfile {
            name: "Atari-RL",
            params: 4_000_000,
            flops_fwd_per_sample: 0.4e9,
            sample_bytes: 0, // generated in-function by the simulator
            extra_upload_bytes: 160 << 20,
            model_init_s: 1.5,
        }
    }

    /// GPT-XL-class decoder (~1.3 B parameters, 256-token sequences):
    /// the "model too big for one function" benchmark. Its optimizer
    /// residency (3x gradients ~ 14.9 GB) exceeds every FaaS memory size
    /// (`mem_max_mb` = 10 240), so pure data parallelism always runs
    /// under the 4x thrash penalty — pipeline partitioning is the only
    /// way to fit it, which is exactly the FuncPipe scenario family
    /// fig19 maps.
    pub fn gpt_xl() -> Self {
        ModelProfile {
            name: "GPT-XL",
            params: 1_300_000_000,
            flops_fwd_per_sample: 2.0 * 1.3e9 * 256.0,
            sample_bytes: 2 * 256, // token ids
            extra_upload_bytes: 0,
            model_init_s: 8.0,
        }
    }

    pub fn all() -> Vec<ModelProfile> {
        vec![
            Self::resnet18(),
            Self::resnet50(),
            Self::bert_small(),
            Self::bert_medium(),
            Self::atari_rl(),
        ]
    }

    /// Our own AOT transformer variants, so real runs and simulated runs
    /// share one code path (calibration).
    pub fn from_variant(v: &crate::runtime::VariantSpec) -> Self {
        let tokens = v.seq_len as f64;
        ModelProfile {
            name: "smlt-transformer",
            params: v.n_params as u64,
            flops_fwd_per_sample: 2.0 * v.n_params as f64 * tokens,
            sample_bytes: 4 * (v.seq_len as u64 + 1),
            extra_upload_bytes: 0,
            model_init_s: 1.0,
        }
    }
}

/// Calibration constants for iteration-time prediction.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// sustained GFLOP/s of one serverless vCPU on dense training math.
    /// Default calibrated from real PJRT runs of the `base` variant
    /// (EXPERIMENTS.md §Calibration).
    pub gflops_per_vcpu: f64,
    /// backward-pass cost multiplier (fwd+bwd ~= 3x fwd)
    pub bwd_multiplier: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration { gflops_per_vcpu: 9.0, bwd_multiplier: 3.0 }
    }
}

/// Per-iteration compute time of one worker processing `per_worker_batch`
/// samples at `mem_mb` memory.
pub fn compute_time_s(
    profile: &ModelProfile,
    cal: &Calibration,
    platform: &FaasPlatform,
    mem_mb: u32,
    per_worker_batch: u32,
) -> f64 {
    let vcpus = platform.vcpus(mem_mb).max(0.08); // tiny functions still run
    let flops = profile.flops_fwd_per_sample * cal.bwd_multiplier * per_worker_batch as f64;
    // memory pressure penalty: if the model + activations don't fit, the
    // function thrashes (the paper's motivation for right-sizing memory)
    let need_mb = (profile.grad_bytes() * 3) as f64 / (1 << 20) as f64
        + per_worker_batch as f64 * profile.sample_bytes as f64 / (1 << 20) as f64;
    let pressure = if (mem_mb as f64) < need_mb { 4.0 } else { 1.0 };
    pressure * flops / (vcpus * cal.gflops_per_vcpu * 1e9)
}

/// Full per-worker init time when a function (re)starts.
pub fn init_time_s(profile: &ModelProfile, fw: Framework, cold_start_s: f64) -> f64 {
    cold_start_s + fw.init_base_s() + profile.model_init_s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::faas::FaasPlatform;

    fn platform() -> FaasPlatform {
        FaasPlatform::with_seed(0)
    }

    #[test]
    fn profiles_ordered_by_size() {
        let p = ModelProfile::all();
        assert!(p[0].params < p[1].params);
        assert!(p[2].params < p[3].params);
        assert_eq!(p[3].grad_bytes(), 440_000_000);
    }

    #[test]
    fn more_memory_is_faster_until_vcpu_cap() {
        let pf = platform();
        let cal = Calibration::default();
        let m = ModelProfile::resnet18();
        let t1 = compute_time_s(&m, &cal, &pf, 1769, 32);
        let t3 = compute_time_s(&m, &cal, &pf, 3 * 1769, 32);
        assert!(t3 < t1 / 2.5, "3 vCPU ~3x faster: {t1} vs {t3}");
        let t10 = compute_time_s(&m, &cal, &pf, 10_240, 32);
        let t10b = compute_time_s(&m, &cal, &pf, 10_240 + 0, 32);
        assert!((t10 - t10b).abs() < 1e-12);
    }

    #[test]
    fn memory_pressure_penalizes_undersized_functions() {
        let pf = platform();
        let cal = Calibration::default();
        let m = ModelProfile::bert_medium(); // needs ~1.3 GB for grads x3
        let cramped = compute_time_s(&m, &cal, &pf, 768, 8);
        let roomy = compute_time_s(&m, &cal, &pf, 4096, 8);
        // roomy has more vCPUs AND no pressure penalty
        assert!(cramped > roomy * 4.0);
    }

    #[test]
    fn atari_uploads_more_than_resnet50_despite_smaller_model() {
        let atari = ModelProfile::atari_rl();
        let r50 = ModelProfile::resnet50();
        assert!(atari.params < r50.params);
        assert!(
            atari.grad_bytes() + atari.extra_upload_bytes
                > r50.grad_bytes() + r50.extra_upload_bytes
        );
    }

    #[test]
    fn gpt_xl_exceeds_every_function_memory_size() {
        let pf = platform();
        let g = ModelProfile::gpt_xl();
        let need_mb = (g.grad_bytes() * 3) as f64 / (1 << 20) as f64;
        assert!(
            need_mb > pf.limits.mem_max_mb as f64,
            "gpt_xl must not fit one function: needs {need_mb} MB"
        );
        // ... so data-parallel compute always carries the thrash penalty
        let cal = Calibration::default();
        let t_max = compute_time_s(&g, &cal, &pf, pf.limits.mem_max_mb, 8);
        let vcpus = pf.vcpus(pf.limits.mem_max_mb).max(0.08);
        let unthrashed =
            g.flops_fwd_per_sample * cal.bwd_multiplier * 8.0 / (vcpus * cal.gflops_per_vcpu * 1e9);
        assert!((t_max - 4.0 * unthrashed).abs() < 1e-9 * t_max.abs().max(1.0));
    }

    #[test]
    fn activation_bytes_scale_sublinearly_with_params() {
        let small = ModelProfile::resnet18();
        let big = ModelProfile::gpt_xl();
        let (a, b) = (small.activation_bytes_per_sample(), big.activation_bytes_per_sample());
        assert!(b > a, "bigger model, wider boundary tensor");
        // sqrt scaling: ~111x the params, ~10.5x the activation bytes
        assert!((b as f64) < (a as f64) * (big.params as f64 / small.params as f64));
        // sane absolute magnitude: ~1.15 MB/sample for GPT-XL
        assert!((1 << 20..4 << 20).contains(&(b as usize)), "gpt_xl act {b} B");
    }

    #[test]
    fn init_time_includes_framework_and_model() {
        let m = ModelProfile::resnet18();
        let t = init_time_s(&m, Framework::Tensorflow, 0.4);
        // the paper cites ~4 s for ResNet-18 on TF
        assert!((3.5..6.0).contains(&t), "init {t}");
        assert!(
            init_time_s(&m, Framework::Pytorch, 0.4) < t,
            "pytorch inits faster than tf in our profile"
        );
    }

    #[test]
    fn variant_profile_consistent() {
        use crate::runtime::Manifest;
        let root = Manifest::default_root();
        if !root.join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(root).unwrap();
        let v = m.variant("tiny").unwrap();
        let p = ModelProfile::from_variant(v);
        assert_eq!(p.params, v.n_params as u64);
        assert!(p.flops_fwd_per_sample > 0.0);
    }
}
