//! Memory-leak regression check for the PJRT execution path.
//!
//! The `xla` crate's `execute(Literal...)` leaks its internal input
//! conversions (~one input set per call); `runtime::Engine` therefore
//! routes through explicit buffers + `execute_b`. This binary loops the
//! two hot executables and prints RSS — flat RSS = healthy.
//! (EXPERIMENTS.md §Perf L3, iteration 7.)

use smlt::runtime::{params, Engine, Manifest};
fn rss_mb() -> u64 {
    let s = std::fs::read_to_string("/proc/self/status").unwrap();
    s.lines().find(|l| l.starts_with("VmRSS")).unwrap()
        .split_whitespace().nth(1).unwrap().parse::<u64>().unwrap() / 1024
}
fn main() {
    let mut eng = Engine::new(Manifest::load(Manifest::default_root()).unwrap()).unwrap();
    let spec = eng.manifest().variant("small").unwrap().clone();
    let p = params::init_params(&spec, 0);
    let toks = params::gen_tokens(&spec, 0);
    eng.warm("small").unwrap();
    println!("start rss {} MB", rss_mb());
    for i in 0..30 {
        let _ = eng.grad_step("small", &p, &toks).unwrap();
        if i % 10 == 9 { println!("grad_step {}: rss {} MB", i, rss_mb()); }
    }
    let zeros = vec![0.0f32; spec.n_params];
    for i in 0..30 {
        let _ = eng.apply_update("small", &p, &zeros, &zeros, &p, 1e-3).unwrap();
        if i % 10 == 9 { println!("apply {}: rss {} MB", i, rss_mb()); }
    }
}
