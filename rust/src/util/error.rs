//! Minimal error plumbing (the offline crate registry carries no `anyhow`):
//! a string-backed [`Error`], a [`Result`] alias, the [`anyhow!`] macro and
//! a [`Context`] trait — the exact subset of the `anyhow` API this crate
//! uses, so the call sites read identically.
//!
//! Like `anyhow::Error`, [`Error`] deliberately does **not** implement
//! `std::error::Error`: that is what allows the blanket
//! `From<E: std::error::Error>` conversion behind `?` without colliding
//! with the reflexive `From<T> for T` impl.

use std::fmt;

/// A boxed-string error: cheap to construct, formats as its message.
pub struct Error(String);

impl Error {
    pub fn msg(m: impl fmt::Display) -> Error {
        Error(m.to_string())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> Result<()>` prints errors via Debug; show the
        // message, not a struct dump
        f.write_str(&self.0)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error(e.to_string())
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow!("fmt {args}")` — construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

// Re-export so `use crate::util::error::anyhow;` works like the crate it
// replaces (`#[macro_export]` itself only exports at the crate root).
pub use crate::anyhow;

/// Attach context to an error, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T>;
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.map_err(|e| Error(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.ok_or_else(|| Error(msg.to_string()))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error(f().to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<u32> {
        Err(anyhow!("broke at step {}", 3))
    }

    #[test]
    fn macro_formats_and_displays() {
        let e = fails().unwrap_err();
        assert_eq!(e.to_string(), "broke at step 3");
        assert_eq!(format!("{e:?}"), "broke at step 3");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn parse(s: &str) -> Result<f64> {
            Ok(s.parse::<f64>()?)
        }
        assert!(parse("1.5").is_ok());
        assert!(parse("nope").unwrap_err().to_string().contains("float"));
    }

    #[test]
    fn context_wraps_both_results_and_options() {
        let r: std::result::Result<(), std::fmt::Error> = Err(std::fmt::Error);
        let e = r.context("writing table").unwrap_err();
        assert!(e.to_string().starts_with("writing table: "));
        let o: Option<u8> = None;
        let e = o.with_context(|| format!("missing {}", "key")).unwrap_err();
        assert_eq!(e.to_string(), "missing key");
    }
}
