//! Small statistics helpers used by benches, metrics, and the optimizer.

/// Summary of a sample: mean / std / percentiles.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p25: f64,
    pub p50: f64,
    pub p75: f64,
    pub p95: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    if xs.is_empty() {
        return Summary::default();
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len();
    let mean = s.iter().sum::<f64>() / n as f64;
    let var = s.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
    Summary {
        n,
        mean,
        std: var.sqrt(),
        min: s[0],
        p25: percentile_sorted(&s, 0.25),
        p50: percentile_sorted(&s, 0.50),
        p75: percentile_sorted(&s, 0.75),
        p95: percentile_sorted(&s, 0.95),
        max: s[n - 1],
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Empirical CDF evaluation points: returns (sorted values, cumulative probs).
pub fn ecdf(xs: &[f64]) -> (Vec<f64>, Vec<f64>) {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = s.len();
    let probs = (1..=n).map(|i| i as f64 / n as f64).collect();
    (s, probs)
}

/// Standard normal PDF.
pub fn norm_pdf(x: f64) -> f64 {
    (-0.5 * x * x).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Standard normal CDF (Abramowitz & Stegun 7.1.26 via erf approximation).
pub fn norm_cdf(x: f64) -> f64 {
    0.5 * (1.0 + erf(x / std::f64::consts::SQRT_2))
}

/// Standard normal quantile (inverse CDF) via Acklam's rational
/// approximation; |relative err| < 1.15e-9 over (0, 1). Used by the
/// straggler model's order-statistic quantiles.
pub fn norm_ppf(p: f64) -> f64 {
    if !(0.0..=1.0).contains(&p) {
        return f64::NAN;
    }
    if p == 0.0 {
        return f64::NEG_INFINITY;
    }
    if p == 1.0 {
        return f64::INFINITY;
    }
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// Blom plotting position for the k-th of n order statistics:
/// `(k - 0.375) / (n + 0.25)`, with `n` clamped to at least 1 and `k`
/// clamped into `[1, n]`. Strictly increasing in `k`, always in (0, 1).
pub fn blom_position(k: u32, n: u32) -> f64 {
    let n = n.max(1);
    let k = k.clamp(1, n);
    (k as f64 - 0.375) / (n as f64 + 0.25)
}

/// Expected k-th order statistic of `n` i.i.d. draws from the
/// distribution with quantile function `quantile`, via the Blom
/// approximation `F⁻¹(blom_position(k, n))` — smooth and deterministic,
/// which is what an analytic planner needs where a Monte Carlo estimate
/// would jitter. Near-exact for the normal family (Blom's original
/// target); a few percent high in the extreme tail of heavy-tailed
/// distributions (checked against Monte Carlo in the tests below).
/// [`StragglerModel::expected_kth`] delegates here.
///
/// [`StragglerModel::expected_kth`]: crate::sync::StragglerModel::expected_kth
pub fn expected_kth(quantile: impl Fn(f64) -> f64, k: u32, n: u32) -> f64 {
    quantile(blom_position(k, n))
}

/// erf via A&S 7.1.26; |err| < 1.5e-7, plenty for EI acquisition.
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736)
            * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = summarize(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
    }

    #[test]
    fn percentile_interpolates() {
        let s = [0.0, 10.0];
        assert!((percentile_sorted(&s, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_symmetry_and_bounds() {
        assert!((norm_cdf(0.0) - 0.5).abs() < 1e-7);
        assert!((norm_cdf(1.96) - 0.975).abs() < 1e-3);
        assert!((norm_cdf(-1.96) - 0.025).abs() < 1e-3);
    }

    #[test]
    fn pdf_peak() {
        assert!((norm_pdf(0.0) - 0.3989422804).abs() < 1e-8);
        assert!(norm_pdf(3.0) < norm_pdf(0.0));
    }

    #[test]
    fn ppf_inverts_cdf() {
        for p in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.975, 0.99] {
            let x = norm_ppf(p);
            assert!((norm_cdf(x) - p).abs() < 1e-6, "p={p} x={x}");
        }
        assert!((norm_ppf(0.5)).abs() < 1e-9);
        assert!((norm_ppf(0.975) - 1.959964).abs() < 1e-5);
        assert!(norm_ppf(0.0) == f64::NEG_INFINITY);
        assert!(norm_ppf(1.0) == f64::INFINITY);
        assert!(norm_ppf(-0.1).is_nan());
    }

    #[test]
    fn ecdf_monotone() {
        let (v, p) = ecdf(&[3.0, 1.0, 2.0]);
        assert_eq!(v, vec![1.0, 2.0, 3.0]);
        assert_eq!(p, vec![1.0 / 3.0, 2.0 / 3.0, 1.0]);
    }

    #[test]
    fn blom_position_clamped_and_increasing() {
        assert!((blom_position(1, 16) - 0.625 / 16.25).abs() < 1e-15);
        // degenerate inputs clamp instead of leaving (0, 1)
        assert_eq!(blom_position(0, 16), blom_position(1, 16));
        assert_eq!(blom_position(99, 16), blom_position(16, 16));
        assert_eq!(blom_position(1, 0), blom_position(1, 1));
        let mut prev = 0.0;
        for k in 1..=16 {
            let p = blom_position(k, 16);
            assert!(p > prev && p < 1.0, "k={k}: {p}");
            prev = p;
        }
    }

    /// Empirical mean of the k-th order statistic of `n` draws from
    /// `sample`, over `reps` replicates at a fixed seed.
    fn mc_kth(
        sample: impl Fn(&mut crate::util::rng::Pcg) -> f64,
        k: usize,
        n: usize,
        reps: usize,
        seed: u64,
    ) -> f64 {
        let mut rng = crate::util::rng::Pcg::new(seed);
        let mut acc = 0.0;
        let mut buf = vec![0.0f64; n];
        for _ in 0..reps {
            for b in buf.iter_mut() {
                *b = sample(&mut rng);
            }
            buf.sort_by(|a, b| a.partial_cmp(b).unwrap());
            acc += buf[k - 1];
        }
        acc / reps as f64
    }

    #[test]
    fn expected_kth_tracks_monte_carlo_normal() {
        // Blom's approximation was derived for the normal family: the
        // error is ~1e-2 at n = 16, and the MC standard error at 2000
        // replicates is ~1.5e-2, so an absolute 0.08 band is generous.
        let n = 16;
        for k in [4u32, 8, 13, 16] {
            let blom = expected_kth(norm_ppf, k, n);
            let mc = mc_kth(|r| r.normal(), k as usize, n as usize, 2000, 0xB10 + k as u64);
            assert!(
                (blom - mc).abs() < 0.08,
                "normal k={k}/{n}: blom {blom} vs mc {mc}"
            );
        }
    }

    #[test]
    fn expected_kth_tracks_monte_carlo_exponential() {
        // Exp(1): quantile -ln(1 - q). Blom runs a few percent high in
        // the extreme tail (k = n = 16: 3.26 vs the exact H_16 = 3.38,
        // ~4%), so the band is 12% relative — wide enough for that bias
        // plus 3 MC standard errors, tight enough to catch a wrong
        // plotting position (k/(n+1) would miss the max by ~20%).
        let n = 16;
        for k in [8u32, 13, 16] {
            let blom = expected_kth(|q| -(1.0 - q).ln(), k, n);
            let mc = mc_kth(
                |r| -(1.0 - r.next_f64()).ln(),
                k as usize,
                n as usize,
                2000,
                0xE49 + k as u64,
            );
            assert!(
                (blom - mc).abs() < 0.12 * mc.abs().max(0.5),
                "exp k={k}/{n}: blom {blom} vs mc {mc}"
            );
        }
    }

    #[test]
    fn expected_kth_at_k_equals_n_agrees_with_the_max() {
        // k == n must estimate the sample maximum: compare against the
        // empirical mean of max(n draws) directly.
        let n = 12;
        let blom = expected_kth(norm_ppf, n, n);
        let mut rng = crate::util::rng::Pcg::new(0xA77);
        let mut acc = 0.0;
        let reps = 2000;
        for _ in 0..reps {
            let mut mx = f64::NEG_INFINITY;
            for _ in 0..n {
                mx = mx.max(rng.normal());
            }
            acc += mx;
        }
        let mc = acc / reps as f64;
        assert!((blom - mc).abs() < 0.08, "max of {n}: blom {blom} vs mc {mc}");
        // and k = n dominates every interior order statistic
        for k in 1..n {
            assert!(expected_kth(norm_ppf, k, n) < blom);
        }
    }
}
