//! Plain-text table + CSV emission for benches (paper figures/tables).

use std::io::Write;
use std::path::Path;

/// Column-aligned table printed to stdout and mirrored to a CSV file.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn rowf(&mut self, cells: &[&dyn std::fmt::Display]) {
        self.row(&cells.iter().map(|c| format!("{c}")).collect::<Vec<_>>());
    }

    pub fn print(&self) {
        println!("\n== {} ==", self.title);
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("  ")
        };
        println!("{}", fmt_row(&self.header));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }

    /// Write the table as CSV under `bench_out/`.
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.header.join(","))?;
        for row in &self.rows {
            writeln!(f, "{}", row.join(","))?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_writes() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.rowf(&[&3, &4.5]);
        let dir = std::env::temp_dir().join("smlt_table_test.csv");
        t.write_csv(&dir).unwrap();
        let text = std::fs::read_to_string(&dir).unwrap();
        assert!(text.starts_with("a,b\n1,2\n3,4.5"));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into()]);
    }
}
