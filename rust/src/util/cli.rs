//! Tiny CLI argument parser (offline env has no `clap`).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional args.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(body) = a.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn mixed_forms() {
        // convention: positionals precede flags (a bare `--flag positional`
        // would be read as `--flag=positional`, which is documented)
        let a = parse("train extra --model base --steps=100 --verbose");
        assert_eq!(a.positional, vec!["train", "extra"]);
        assert_eq!(a.get("model"), Some("base"));
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.get_or("model", "tiny"), "tiny");
        assert_eq!(a.get_f64("lr", 0.5), 0.5);
        assert!(!a.has_flag("quiet"));
    }

    #[test]
    fn flag_followed_by_option() {
        let a = parse("--dry-run --out dir");
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get("out"), Some("dir"));
    }
}
