//! Minimal JSON parser/writer (the offline crate registry carries no serde).
//!
//! Supports the full JSON grammar the SMLT manifest and config files use:
//! objects, arrays, strings (with escapes), numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0);
        out
    }

    fn write(&self, out: &mut String, indent: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                if v.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(", ");
                    }
                    e.write(out, indent + 1);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                if m.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push_str("{\n");
                let pad = " ".repeat((indent + 1) * 2);
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    out.push_str(&pad);
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1);
                }
                out.push('\n');
                out.push_str(&" ".repeat(indent * 2));
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            None => Err("unexpected eof".into()),
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(_) => self.number(),
        }
    }

    fn lit(&mut self, pat: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(pat.as_bytes()) {
            self.i += pat.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        if self.b.get(self.i) != Some(&b'"') {
            return Err(format!("expected string at byte {}", self.i));
        }
        self.i += 1;
        let mut out = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err("bad escape".into()),
                    }
                    self.i += 1;
                }
                Some(&c) => {
                    // collect a run of plain bytes (fast path, keeps UTF-8 intact)
                    let start = self.i;
                    let mut j = self.i;
                    while j < self.b.len() && self.b[j] != b'"' && self.b[j] != b'\\' {
                        j += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..j]).map_err(|e| e.to_string())?,
                    );
                    self.i = j;
                    let _ = c;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.i += 1; // [
        let mut v = Vec::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("bad array at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.i += 1; // {
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.b.get(self.i) == Some(&b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            if self.b.get(self.i) != Some(&b':') {
                return Err(format!("expected ':' at byte {}", self.i));
            }
            self.i += 1;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("bad object at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "x\ny"}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1], Json::Num(2.5));
        assert_eq!(v.get("e").unwrap().as_str().unwrap(), "x\ny");
        let re = Json::parse(&v.to_string_pretty()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("123abc").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Ab""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Ab");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let m = Json::parse(&text).unwrap();
            assert!(m.get("variants").is_some());
        }
    }
}
