//! Deterministic PRNG (PCG-XSH-RR 64/32) + the shared-LCG init scheme.
//!
//! The `Lcg` type mirrors `python/compile/model.py::lcg_uniform` bit-for-bit
//! so the Rust coordinator initializes exactly the parameters the AOT smoke
//! record was computed with.

/// PCG-XSH-RR 64/32: small, fast, statistically solid; used everywhere the
/// framework needs randomness (simulators, optimizers, property tests).
#[derive(Clone, Debug)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

pub const LCG_MUL: u64 = 6364136223846793005;
pub const LCG_ADD: u64 = 1442695040888963407;

impl Pcg {
    pub fn new(seed: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (seed << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(LCG_MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // multiply-shift; bias negligible for our n « 2^64
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-12);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda`.
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        -self.next_f64().max(1e-12).ln() / lambda
    }

    /// Log-normal parameterized by the *underlying* normal's (mu, sigma).
    pub fn lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.normal()).exp()
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }
}

/// Raw LCG shared with `python/compile/model.py` (param init / token gen).
#[derive(Clone, Copy, Debug)]
pub struct Lcg(pub u64);

impl Lcg {
    pub fn step(&mut self) -> u64 {
        self.0 = self.0.wrapping_mul(LCG_MUL).wrapping_add(LCG_ADD);
        self.0
    }

    /// f32 in [-1, 1); bit-identical to python `lcg_uniform`.
    pub fn uniform_f32(&mut self) -> f32 {
        let x = self.step();
        let u24 = (x >> 40) as f64;
        ((u24 / (1u64 << 24) as f64) * 2.0 - 1.0) as f32
    }
}

/// FNV-1a 64-bit hash, mirroring python `_fnv1a` (per-tensor init seeds).
pub fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xCBF29CE484222325;
    for b in s.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x100000001B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg_deterministic() {
        let mut a = Pcg::new(42);
        let mut b = Pcg::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn pcg_uniform_range() {
        let mut r = Pcg::new(7);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn pcg_below_in_range_and_covers() {
        let mut r = Pcg::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.below(10) as usize;
            assert!(x < 10);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn fnv1a_offset_basis() {
        assert_eq!(fnv1a(""), 0xCBF29CE484222325);
        assert_ne!(fnv1a("tok_emb"), fnv1a("pos_emb"));
    }

    #[test]
    fn lcg_uniform_bounds() {
        let mut l = Lcg(123);
        for _ in 0..1000 {
            let x = l.uniform_f32();
            assert!((-1.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
