//! Infrastructure utilities: PRNG, JSON, CLI parsing, statistics, tables.
//!
//! Hand-rolled because the offline crate registry only carries the `xla`
//! dependency closure (see DESIGN.md §3 substitutions).

pub mod cli;
pub mod error;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
