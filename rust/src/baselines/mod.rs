//! Comparison systems (§5): Siren, Cirrus, LambdaML, MLCD, IaaS.
//!
//! Each baseline is characterized by the axes the paper varies:
//! synchronization scheme, invocation pattern, substrate (FaaS vs VM),
//! adaptivity (does it re-optimize resources when the workload changes?)
//! and how/whether it profiles before training. The shared simulation
//! driver in [`crate::coordinator::simrun`] interprets these descriptors,
//! so every figure compares systems under identical workloads.

use crate::faas::InvokeMode;
use crate::sync::Scheme;

/// Which system runs the training job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// this paper: hierarchical sync, task scheduler, adaptive BO
    Smlt,
    /// Wang et al.: serverless PS via cloud storage, fixed resources
    /// (their RL tunes worker count offline; modeled as fixed + central
    /// storage sync, per §2.2/Fig 1)
    Siren,
    /// Carreira et al.: serverless workers + dedicated PS endpoint
    Cirrus,
    /// Jiang et al.: serverless ScatterReduce via object store, fixed
    /// user-chosen resources, async function-to-function invocation
    LambdaMl,
    /// Yi et al.: VM-based MLaaS; Bayesian optimizer runs *once* before
    /// training (profiling on VMs is expensive), then fixed VMs
    Mlcd,
    /// plain VM cluster, user-managed, always-on
    Iaas,
}

impl SystemKind {
    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::Smlt => "SMLT",
            SystemKind::Siren => "Siren",
            SystemKind::Cirrus => "Cirrus",
            SystemKind::LambdaMl => "LambdaML",
            SystemKind::Mlcd => "MLCD",
            SystemKind::Iaas => "IaaS",
        }
    }

    pub fn all() -> [SystemKind; 6] {
        [
            SystemKind::Smlt,
            SystemKind::Siren,
            SystemKind::Cirrus,
            SystemKind::LambdaMl,
            SystemKind::Mlcd,
            SystemKind::Iaas,
        ]
    }

    /// Serverless systems run on the FaaS substrate; MLCD/IaaS on VMs.
    pub fn is_serverless(&self) -> bool {
        !matches!(self, SystemKind::Mlcd | SystemKind::Iaas)
    }

    /// Gradient-synchronization scheme (serverless systems only; VM
    /// systems use in-cluster ring allreduce over the VM NIC).
    pub fn scheme(&self) -> Option<Scheme> {
        match self {
            SystemKind::Smlt => Some(Scheme::SmltHierarchical),
            SystemKind::Siren => Some(Scheme::SirenCentral),
            SystemKind::Cirrus => Some(Scheme::CirrusPs),
            SystemKind::LambdaMl => Some(Scheme::LambdaMlScatterReduce),
            _ => None,
        }
    }

    /// How workers get launched (determines which FaaS quirks bite).
    pub fn invoke_mode(&self) -> InvokeMode {
        match self {
            SystemKind::Smlt => InvokeMode::DirectTracked,
            SystemKind::LambdaMl => InvokeMode::AsyncChained,
            SystemKind::Siren | SystemKind::Cirrus => InvokeMode::StepFunctionsMap,
            _ => InvokeMode::DirectTracked,
        }
    }

    /// Does the system re-optimize resources when training dynamics
    /// change (batch size / model size)? Only SMLT (§3.1).
    pub fn adaptive(&self) -> bool {
        matches!(self, SystemKind::Smlt)
    }

    /// Does the system profile/optimize before training at all?
    pub fn optimizes_initial_config(&self) -> bool {
        matches!(self, SystemKind::Smlt | SystemKind::Mlcd)
    }

    /// Does an external task scheduler amortize init across the duration
    /// cap (§4.1)? Without it, every restart pays full re-init.
    pub fn amortizes_init(&self) -> bool {
        matches!(self, SystemKind::Smlt)
    }

    /// Honors user deadline/budget goals?
    pub fn user_centric(&self) -> bool {
        matches!(self, SystemKind::Smlt)
    }

    /// VM systems keep instances running between bursts (idle cost);
    /// serverless pays per use.
    pub fn pays_idle(&self) -> bool {
        matches!(self, SystemKind::Mlcd | SystemKind::Iaas)
    }
}

/// Ring-allreduce time on a VM cluster (the MLCD/IaaS sync path):
/// 2 (n-1)/n * G bytes per worker over the VM NIC.
pub fn vm_allreduce_s(grad_bytes: u64, n: u32, nic_bps: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let vol = 2.0 * (n as f64 - 1.0) / n as f64 * grad_bytes as f64;
    0.001 * (n as f64).log2().ceil() + vol / nic_bps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn only_smlt_is_fully_adaptive_and_user_centric() {
        for s in SystemKind::all() {
            assert_eq!(s.adaptive(), s == SystemKind::Smlt);
            assert_eq!(s.user_centric(), s == SystemKind::Smlt);
        }
    }

    #[test]
    fn serverless_vs_vm_split() {
        assert!(SystemKind::Smlt.is_serverless());
        assert!(SystemKind::LambdaMl.is_serverless());
        assert!(!SystemKind::Mlcd.is_serverless());
        assert!(!SystemKind::Iaas.is_serverless());
        assert!(SystemKind::Mlcd.scheme().is_none());
        assert!(SystemKind::Smlt.scheme().is_some());
    }

    #[test]
    fn vm_systems_pay_idle() {
        assert!(SystemKind::Iaas.pays_idle());
        assert!(!SystemKind::Smlt.pays_idle());
    }

    #[test]
    fn allreduce_scales_gently() {
        let g = 100_000_000;
        let bw = 10e9 / 8.0;
        let t2 = vm_allreduce_s(g, 2, bw);
        let t16 = vm_allreduce_s(g, 16, bw);
        // ring volume asymptotes at 2G: 16 workers < 2x the 2-worker time
        assert!(t16 < t2 * 2.0);
        assert_eq!(vm_allreduce_s(g, 1, bw), 0.0);
    }

    #[test]
    fn mlcd_optimizes_once_lambdaml_never() {
        assert!(SystemKind::Mlcd.optimizes_initial_config());
        assert!(!SystemKind::LambdaMl.optimizes_initial_config());
        assert!(!SystemKind::Mlcd.adaptive());
    }
}
