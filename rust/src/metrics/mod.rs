//! Run metrics: per-iteration records + aggregation for EXPERIMENTS.md,
//! per-tenant fairness / shock-degradation roll-ups ([`fairness`]), the
//! per-tenant billing view of a fleet run ([`billing`]), and the exact
//! per-job time/cost attribution pass over recorded traces
//! ([`attribution`]).

pub mod attribution;
pub mod billing;
pub mod fairness;

pub use attribution::{
    attribute_fleet, attribute_job, attribute_sim, attributed_fleet_cost, CostAttribution,
    JobAttribution, TimeAttribution,
};
pub use billing::{BillingReport, TenantBill};
pub use fairness::{dominant_share, jain_index, FairnessReport, SloMiss, TenantFairness};

use crate::util::json::Json;
use crate::util::stats::{summarize, Summary};
use std::io::Write;
use std::path::Path;

/// One training-iteration record (virtual or wall time, seconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterRecord {
    pub iter: u64,
    pub t_start: f64,
    pub compute_s: f64,
    pub comm_s: f64,
    pub loss: f32,
    pub workers: u32,
    pub mem_mb: u32,
    pub batch_global: u32,
    pub restarted_workers: u32,
}

impl IterRecord {
    pub fn total_s(&self) -> f64 {
        self.compute_s + self.comm_s
    }

    /// JSON view of the record. f64 fields round-trip exactly through the
    /// writer's shortest-representation formatting, so serialized streams
    /// are fit for bit-exact golden-trace comparison.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("iter".to_string(), Json::Num(self.iter as f64));
        m.insert("t_start".to_string(), Json::Num(self.t_start));
        m.insert("compute_s".to_string(), Json::Num(self.compute_s));
        m.insert("comm_s".to_string(), Json::Num(self.comm_s));
        m.insert("loss".to_string(), Json::Num(self.loss as f64));
        m.insert("workers".to_string(), Json::Num(self.workers as f64));
        m.insert("mem_mb".to_string(), Json::Num(self.mem_mb as f64));
        m.insert("batch_global".to_string(), Json::Num(self.batch_global as f64));
        m.insert(
            "restarted_workers".to_string(),
            Json::Num(self.restarted_workers as f64),
        );
        Json::Obj(m)
    }
}

/// Collector for a whole training run.
#[derive(Clone, Debug, Default)]
pub struct RunMetrics {
    pub records: Vec<IterRecord>,
    pub restarts: u64,
    pub failures_detected: u64,
    pub reconfigurations: u64,
}

impl RunMetrics {
    pub fn push(&mut self, r: IterRecord) {
        self.restarts += r.restarted_workers as u64;
        self.records.push(r);
    }

    pub fn total_time_s(&self) -> f64 {
        self.records.iter().map(|r| r.total_s()).sum()
    }

    pub fn compute_summary(&self) -> Summary {
        summarize(&self.records.iter().map(|r| r.compute_s).collect::<Vec<_>>())
    }

    pub fn comm_summary(&self) -> Summary {
        summarize(&self.records.iter().map(|r| r.comm_s).collect::<Vec<_>>())
    }

    /// Throughput (samples/s) over a trailing window ending at `iter`.
    /// 0.0 when no records exist (an empty run has no throughput — and
    /// `records.len() - 1` would underflow).
    pub fn throughput_at(&self, idx: usize, window: usize) -> f64 {
        if self.records.is_empty() {
            return 0.0;
        }
        let lo = idx.saturating_sub(window.saturating_sub(1));
        let slice = &self.records[lo..=idx.min(self.records.len() - 1)];
        let samples: f64 = slice.iter().map(|r| r.batch_global as f64).sum();
        let time: f64 = slice.iter().map(|r| r.total_s()).sum();
        if time > 0.0 {
            samples / time
        } else {
            0.0
        }
    }

    /// JSON array of all per-iteration records (golden-trace fixtures).
    pub fn records_json(&self) -> Json {
        Json::Arr(self.records.iter().map(|r| r.to_json()).collect())
    }

    /// Dump per-iteration CSV (loss curves, throughput traces).
    pub fn write_csv(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        let path = path.as_ref();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(
            f,
            "iter,t_start,compute_s,comm_s,loss,workers,mem_mb,batch_global,restarted_workers"
        )?;
        for r in &self.records {
            writeln!(
                f,
                "{},{:.4},{:.4},{:.4},{:.5},{},{},{},{}",
                r.iter, r.t_start, r.compute_s, r.comm_s, r.loss, r.workers,
                r.mem_mb, r.batch_global, r.restarted_workers
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(iter: u64, comp: f64, comm: f64, batch: u32) -> IterRecord {
        IterRecord {
            iter,
            compute_s: comp,
            comm_s: comm,
            batch_global: batch,
            ..Default::default()
        }
    }

    #[test]
    fn accumulates_and_summarizes() {
        let mut m = RunMetrics::default();
        for i in 0..10 {
            m.push(rec(i, 1.0, 0.5, 64));
        }
        assert!((m.total_time_s() - 15.0).abs() < 1e-12);
        assert!((m.compute_summary().mean - 1.0).abs() < 1e-12);
        assert!((m.comm_summary().p50 - 0.5).abs() < 1e-12);
    }

    #[test]
    fn throughput_windows() {
        let mut m = RunMetrics::default();
        m.push(rec(0, 1.0, 0.0, 100));
        m.push(rec(1, 1.0, 0.0, 300));
        assert!((m.throughput_at(1, 1) - 300.0).abs() < 1e-9);
        assert!((m.throughput_at(1, 2) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_on_empty_run_is_zero() {
        let m = RunMetrics::default();
        assert_eq!(m.throughput_at(0, 8), 0.0);
        assert_eq!(m.throughput_at(5, 1), 0.0);
    }

    #[test]
    fn restart_counting() {
        let mut m = RunMetrics::default();
        m.push(IterRecord { restarted_workers: 3, ..Default::default() });
        m.push(IterRecord { restarted_workers: 1, ..Default::default() });
        assert_eq!(m.restarts, 4);
    }

    #[test]
    fn json_records_roundtrip_exactly() {
        let mut m = RunMetrics::default();
        m.push(rec(0, 1.0 / 3.0, 0.123_456_789_012_345_6, 64));
        m.push(rec(1, 2.0, 0.5, 128));
        let text = m.records_json().to_string_pretty();
        let parsed = Json::parse(&text).unwrap();
        assert_eq!(parsed, m.records_json(), "shortest-repr f64 must round-trip");
    }

    #[test]
    fn csv_roundtrip() {
        let mut m = RunMetrics::default();
        m.push(rec(0, 1.0, 0.5, 8));
        let p = std::env::temp_dir().join("smlt_metrics_test.csv");
        m.write_csv(&p).unwrap();
        let text = std::fs::read_to_string(&p).unwrap();
        assert!(text.lines().count() == 2);
        let header = text.lines().next().unwrap();
        let row = text.lines().nth(1).unwrap();
        assert_eq!(
            header.split(',').count(),
            row.split(',').count(),
            "header and rows must have the same arity"
        );
        // the last column holds per-iteration restarted_workers, not the
        // run-level restart total — the header must say so
        assert_eq!(header.split(',').last().unwrap(), "restarted_workers");
    }
}
