//! Per-tenant fairness and shock-degradation metrics for fleet runs.
//!
//! The fleet scheduler answers *who got slots*; this module answers *was
//! that fair, and what did a capacity shock cost each tenant*:
//!
//! - [`jain_index`] — Jain's fairness index over any per-tenant series
//!   (1.0 = perfectly even, 1/n = one tenant took everything),
//! - [`dominant_share`] — the DRF coordinate: a tenant's largest share of
//!   any pooled resource (concurrency slots, aggregate function memory),
//! - [`FairnessReport`] — the per-tenant roll-up of a
//!   [`FleetOutcome`](crate::cluster::FleetOutcome): weighted waits,
//!   dominant shares, SLO attribution (did a missed deadline die queueing
//!   or computing?), and per-shock time-to-reoptimize.

use crate::cluster::{FleetOutcome, TenantId};
use crate::coordinator::Goal;

/// Jain's fairness index of `xs`: `(Σx)² / (n · Σx²)`, in `[1/n, 1]`.
/// Degenerate inputs (empty, or all zeros — nobody got anything, which is
/// vacuously even) report 1.0.
pub fn jain_index(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 1.0;
    }
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    if sq <= 0.0 {
        return 1.0;
    }
    (sum * sum) / (xs.len() as f64 * sq)
}

/// Dominant share of a `workers × mem_mb` fleet against an account with
/// `slot_capacity` slots and `mem_capacity_mb` aggregate function memory:
/// the larger of the slot share and the memory share.
pub fn dominant_share(
    workers: u32,
    mem_mb: u32,
    slot_capacity: u32,
    mem_capacity_mb: u64,
) -> f64 {
    let slots = workers as f64 / slot_capacity.max(1) as f64;
    let mem = workers as f64 * mem_mb as f64 / mem_capacity_mb.max(1) as f64;
    slots.max(mem)
}

/// Why a constrained job missed its SLO.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloMiss {
    /// the job met its constraint (or ran unconstrained)
    Met,
    /// missed, and more than half the overrun span was spent parked
    /// waiting for slots — the account, not the job, is to blame
    Queueing,
    /// missed while mostly running — capacity was granted but too little
    /// or too slow (shrunken quota, contention-stretched iterations)
    Capacity,
}

/// One tenant's row in a [`FairnessReport`].
#[derive(Clone, Debug)]
pub struct TenantFairness {
    pub tenant: TenantId,
    /// goal class (Deadline 3 > Budget 2 > Fastest 1 > None 0)
    pub class: u8,
    pub weight: f64,
    pub duration_s: f64,
    pub queue_wait_s: f64,
    /// longest single continuous wait (starvation evidence)
    pub max_wait_streak_s: f64,
    /// fraction of the tenant's span spent parked
    pub wait_fraction: f64,
    /// dominant share of the tenant's *final* fleet configuration
    pub dominant_share: f64,
    pub preemptions: u32,
    pub cost: f64,
    pub slo: SloMiss,
}

/// Fleet-level fairness roll-up; build with [`FairnessReport::from_fleet`].
#[derive(Clone, Debug)]
pub struct FairnessReport {
    pub tenants: Vec<TenantFairness>,
    /// Jain index over weight-normalized durations (lower = the account
    /// favored some tenants' wall clocks)
    pub jain_duration: f64,
    /// Jain index over weight-normalized queue waits
    pub jain_wait: f64,
    /// worst single continuous wait across the fleet
    pub max_wait_streak_s: f64,
    /// per applied shock: virtual seconds from the capacity change until
    /// every victim fleet was re-admitted (`None` = never recovered)
    pub time_to_reoptimize_s: Vec<Option<f64>>,
    /// constrained (Deadline/Budget) jobs that met their SLO
    pub slo_met: u32,
    /// missed SLOs attributed to queueing vs granted-capacity shortfall
    pub slo_missed_queueing: u32,
    pub slo_missed_capacity: u32,
}

impl FairnessReport {
    /// Compute the report from a finished fleet run. The account's
    /// resource axes are taken from the outcome's final limit and the
    /// platform's 10 240 MB per-function ceiling (the same normalization
    /// the DRF arbiter uses).
    pub fn from_fleet(out: &FleetOutcome) -> FairnessReport {
        let slot_cap = out.account_limit.max(1);
        let mem_cap = slot_cap as u64 * crate::faas::FaasLimits::default().mem_max_mb as u64;
        let mut tenants = Vec::with_capacity(out.jobs.len());
        let mut slo_met = 0u32;
        let mut slo_missed_queueing = 0u32;
        let mut slo_missed_capacity = 0u32;
        for j in &out.jobs {
            let duration = j.duration_s();
            let wait_fraction = if duration > 0.0 {
                (j.queue_wait_s / duration).clamp(0.0, 1.0)
            } else {
                0.0
            };
            let (workers, mem_mb) = j
                .outcome
                .config_trace
                .last()
                .map(|(_, c)| (c.workers, c.mem_mb))
                .unwrap_or((0, 0));
            let slo = match j.goal {
                Goal::Deadline { t_max_s } if duration > t_max_s => {
                    if wait_fraction > 0.5 {
                        SloMiss::Queueing
                    } else {
                        SloMiss::Capacity
                    }
                }
                Goal::Budget { s_max } if j.outcome.total_cost() > s_max => {
                    // budget overruns are never queueing's fault — parked
                    // time is free; the granted capacity was too pricey
                    SloMiss::Capacity
                }
                _ => SloMiss::Met,
            };
            match (j.goal, slo) {
                (Goal::Deadline { .. } | Goal::Budget { .. }, SloMiss::Met) => slo_met += 1,
                (_, SloMiss::Queueing) => slo_missed_queueing += 1,
                (_, SloMiss::Capacity) => slo_missed_capacity += 1,
                _ => {}
            }
            tenants.push(TenantFairness {
                tenant: j.tenant,
                class: j.goal.class(),
                weight: j.weight,
                duration_s: duration,
                queue_wait_s: j.queue_wait_s,
                max_wait_streak_s: j.max_wait_streak_s,
                wait_fraction,
                dominant_share: dominant_share(workers, mem_mb, slot_cap, mem_cap),
                preemptions: j.preemptions,
                cost: j.outcome.total_cost(),
                slo,
            });
        }
        let weighted = |f: fn(&TenantFairness) -> f64| -> Vec<f64> {
            tenants.iter().map(|t| f(t) / t.weight.max(1e-9)).collect()
        };
        FairnessReport {
            jain_duration: jain_index(&weighted(|t| t.duration_s)),
            jain_wait: jain_index(&weighted(|t| t.queue_wait_s)),
            max_wait_streak_s: tenants
                .iter()
                .map(|t| t.max_wait_streak_s)
                .fold(0.0, f64::max),
            time_to_reoptimize_s: out
                .shocks
                .iter()
                .map(|s| s.recovered_s.map(|r| r - s.at_s))
                .collect(),
            slo_met,
            slo_missed_queueing,
            slo_missed_capacity,
            tenants,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jain_bounds_and_extremes() {
        assert_eq!(jain_index(&[]), 1.0);
        assert_eq!(jain_index(&[0.0, 0.0]), 1.0);
        assert!((jain_index(&[5.0, 5.0, 5.0]) - 1.0).abs() < 1e-12);
        // one tenant took everything: index collapses to 1/n
        assert!((jain_index(&[9.0, 0.0, 0.0]) - 1.0 / 3.0).abs() < 1e-12);
        // ordering invariance
        assert_eq!(jain_index(&[1.0, 2.0, 3.0]), jain_index(&[3.0, 1.0, 2.0]));
    }

    #[test]
    fn dominant_share_picks_the_binding_resource() {
        // 10 workers on a 100-slot account: slot share 0.1; tiny memory
        assert!((dominant_share(10, 128, 100, 1_024_000) - 0.1).abs() < 1e-12);
        // memory hog: 10 x 10240 MB = 102400 of 1,024,000 → 0.1 either way
        assert!((dominant_share(10, 10_240, 100, 1_024_000) - 0.1).abs() < 1e-12);
        // memory-bound: 4 workers x 10240 on a tight memory pool
        let d = dominant_share(4, 10_240, 100, 81_920);
        assert!((d - 0.5).abs() < 1e-12, "memory should bind: {d}");
        assert_eq!(dominant_share(0, 0, 0, 0), 0.0);
    }
}
