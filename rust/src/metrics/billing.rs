//! Per-tenant billing view of a fleet run: the shared-account invoice,
//! split by who incurred what.
//!
//! The fleet scheduler already keeps one [`CostLedger`] per job, but a
//! platform operator reads the bill the other way around: *which tenant
//! cost what, per service line, and what did the account itself spend on
//! warmth*. [`BillingReport::from_fleet`] rolls a
//! [`FleetOutcome`](crate::cluster::FleetOutcome) up into exactly that —
//! per-tenant service-line totals plus the account-level warm-layer spend
//! (keep-alive + prewarm spawns) that no tenant ledger sees, with the
//! guarantee that the lines sum back to the fleet's own
//! [`total_cost`](crate::cluster::FleetOutcome::total_cost).
//!
//! [`CostLedger`]: crate::costmodel::CostLedger

use crate::cluster::{FleetOutcome, TenantId};
use crate::metrics::fairness::jain_index;

/// One tenant's invoice lines for a fleet run (all $).
#[derive(Clone, Debug)]
pub struct TenantBill {
    pub tenant: TenantId,
    /// goal class (Deadline 3 > Budget 2 > Fastest 1 > None 0)
    pub class: u8,
    /// Lambda compute (GB-seconds + requests)
    pub lambda: f64,
    /// object-store requests (GET + PUT)
    pub s3: f64,
    /// parameter-store container-hours
    pub param_store: f64,
    /// VM-hours (IaaS/MLCD baselines)
    pub vm: f64,
    /// the profiling-phase share of the total (already included in it)
    pub profiling: f64,
    /// everything the tenant's ledger accumulated
    pub total: f64,
    /// worker launches this tenant got served warm
    pub warm_hits: u64,
    /// worker launches this tenant paid cold
    pub cold_starts: u64,
}

/// The fleet invoice: per-tenant bills + the account-level warm spend.
///
/// # Examples
///
/// ```
/// use smlt::baselines::SystemKind;
/// use smlt::cluster::{ClusterParams, ClusterSim, TenantQuota};
/// use smlt::coordinator::{SimJob, Workloads};
/// use smlt::metrics::BillingReport;
/// use smlt::perfmodel::ModelProfile;
///
/// let mut sim = ClusterSim::new(ClusterParams::default());
/// for i in 0..2u64 {
///     let mut job = SimJob::new(
///         SystemKind::Smlt,
///         Workloads::static_run(ModelProfile::resnet18(), 6, 128),
///     );
///     job.seed = 40 + i;
///     sim.submit(job, i as f64 * 100.0, TenantQuota::unlimited());
/// }
/// let out = sim.run();
/// let bill = BillingReport::from_fleet(&out);
/// assert_eq!(bill.tenants.len(), 2);
/// // the invoice reconciles bit-for-bit with the fleet's headline cost
/// assert_eq!(bill.grand_total.to_bits(), out.total_cost().to_bits());
/// ```
#[derive(Clone, Debug)]
pub struct BillingReport {
    /// per-tenant invoices, indexed like the outcome's job list
    pub tenants: Vec<TenantBill>,
    /// sum of the tenant totals
    pub tenant_total: f64,
    /// account-level keep-alive spend (warm pool)
    pub keepalive_cost: f64,
    /// account-level prewarm spawn spend
    pub prewarm_spawn_cost: f64,
    /// tenant totals + warm spend — equals the fleet's `total_cost()`
    pub grand_total: f64,
    /// Jain's index over per-tenant totals (1.0 = everyone paid the
    /// same; 1/n = one tenant footed the whole bill)
    pub jain_cost: f64,
}

impl BillingReport {
    /// Split a finished fleet's ledger by tenant.
    pub fn from_fleet(out: &FleetOutcome) -> BillingReport {
        let tenants: Vec<TenantBill> = out
            .jobs
            .iter()
            .map(|j| {
                let l = &j.outcome.ledger;
                let p = &j.outcome.pricing;
                TenantBill {
                    tenant: j.tenant,
                    class: j.goal.class(),
                    lambda: l.lambda_compute,
                    s3: l.s3_cost(p),
                    param_store: l.param_store,
                    vm: l.vm,
                    profiling: l.profiling,
                    total: j.outcome.total_cost(),
                    warm_hits: j.outcome.warm_hits,
                    cold_starts: j.outcome.cold_starts,
                }
            })
            .collect();
        // identical summation order to FleetOutcome::total_cost so the
        // invoice reconciles bit-for-bit with the headline number
        let tenant_total: f64 = out.jobs.iter().map(|j| j.outcome.total_cost()).sum();
        let totals: Vec<f64> = tenants.iter().map(|t| t.total).collect();
        BillingReport {
            jain_cost: jain_index(&totals),
            tenants,
            tenant_total,
            keepalive_cost: out.warm.keepalive_cost,
            prewarm_spawn_cost: out.warm.spawn_cost,
            grand_total: tenant_total + out.warm.total_cost(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::SystemKind;
    use crate::cluster::{ClusterParams, ClusterSim, TenantQuota};
    use crate::coordinator::{SimJob, Workloads};
    use crate::perfmodel::ModelProfile;
    use crate::warm::WarmParams;

    fn fleet(warm: WarmParams) -> FleetOutcome {
        let mut sim = ClusterSim::new(ClusterParams {
            account_limit: 128,
            warm,
            ..Default::default()
        });
        for i in 0..3u64 {
            let mut j = SimJob::new(
                SystemKind::Smlt,
                Workloads::static_run(ModelProfile::resnet18(), 10, 128),
            );
            j.seed = 800 + i;
            sim.submit(j, i as f64 * 200.0, TenantQuota::unlimited());
        }
        sim.run()
    }

    #[test]
    fn invoice_reconciles_with_fleet_total() {
        for warm in [WarmParams::default(), WarmParams::enabled()] {
            let out = fleet(warm);
            let bill = BillingReport::from_fleet(&out);
            assert_eq!(bill.tenants.len(), 3);
            assert_eq!(
                bill.grand_total.to_bits(),
                out.total_cost().to_bits(),
                "the invoice must reconcile exactly with the headline cost"
            );
            for t in &bill.tenants {
                let lines = t.lambda + t.s3 + t.param_store + t.vm;
                assert!(
                    (lines - t.total).abs() < 1e-9,
                    "tenant {}: lines {} != total {}",
                    t.tenant,
                    lines,
                    t.total
                );
                assert!(t.profiling <= t.total + 1e-12);
            }
            assert!(bill.jain_cost > 0.0 && bill.jain_cost <= 1.0);
        }
    }

    #[test]
    fn warm_spend_is_account_level_not_tenant_level() {
        let out = fleet(WarmParams::enabled());
        let bill = BillingReport::from_fleet(&out);
        if out.warm.hits > 0 {
            assert!(bill.keepalive_cost > 0.0);
        }
        assert!(
            (bill.grand_total - bill.tenant_total
                - bill.keepalive_cost
                - bill.prewarm_spawn_cost)
                .abs()
                < 1e-12
        );
    }
}
