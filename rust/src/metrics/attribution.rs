//! Exact per-job time and cost attribution over recorded traces.
//!
//! Folds a traced job's leaf spans ([`crate::trace`]) into a wall-clock
//! decomposition (queueing / idle / profiling / init / compute / bubble /
//! comm / straggler wait / restart / capacity wait) and its billing
//! ledger into a cost
//! decomposition (profiling / compute / straggler premium / comm /
//! storage) — each with an explicit `unattributed` residual computed as
//! the *last term* of a pinned-order fold:
//!
//! ```text
//! partial       = b1 + b2 + ... + bk          (fixed order)
//! unattributed  = total - partial
//! ```
//!
//! `total_s()` / `total()` re-run the identical fold and add the residual
//! back, so they reproduce the job's `duration_s` / `total_cost()`
//! **bit-exactly** (`==` on `to_bits()`, not an epsilon): whenever
//! `partial` lands within a factor of two of the total — guaranteed by
//! complete span coverage, since the driver emits a leaf span for every
//! virtual-clock advance — Sterbenz's lemma makes `total - partial`
//! exact, and the final add cancels back to `total` exactly. The residual
//! also soaks ordinary float noise from re-tiling the per-iteration
//! segments, so it doubles as a quality signal: large `unattributed`
//! means missing spans, not rounding.
//!
//! The pass is read-only and works on any [`JobOutcome`] /
//! [`SimOutcome`]; untraced runs simply attribute everything to the
//! residual (still bit-exact).

use crate::cluster::{FleetOutcome, JobOutcome, TenantId};
use crate::coordinator::simrun::SimOutcome;
use crate::costmodel::{CostLedger, Pricing};
use crate::trace::{EventKind, TimeBucket, TraceLog};

/// Wall-clock decomposition of one job's arrival-to-completion span.
/// All fields in virtual seconds; `total_s()` reproduces the job's
/// duration bit-exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TimeAttribution {
    /// waiting for slots in the shared account's queue
    pub queueing_s: f64,
    /// declared idle gaps between phases (online-learning traces)
    pub idle_s: f64,
    /// Bayesian-optimizer probe time (initial search + re-optimizations)
    pub profiling_s: f64,
    /// fleet launch: cold/warm startup delay + framework init
    pub init_s: f64,
    /// useful gradient computation (straggler spread and pipeline
    /// bubble peeled out)
    pub compute_s: f64,
    /// pipeline fill/drain bubble
    pub bubble_s: f64,
    /// gradient synchronization (param-store / object-store traffic)
    pub comm_s: f64,
    /// waiting on stragglers past the no-spread baseline
    pub straggler_wait_s: f64,
    /// failure-recovery overhead on the critical path
    pub restart_s: f64,
    /// backoff after `insufficient_capacity` launch refusals
    pub capacity_wait_s: f64,
    /// residual: `duration - (sum of the above)`, exactly
    pub unattributed_s: f64,
}

impl TimeAttribution {
    /// Pinned-order partial sum of the named buckets (no residual).
    fn partial(&self) -> f64 {
        self.queueing_s
            + self.idle_s
            + self.profiling_s
            + self.init_s
            + self.compute_s
            + self.bubble_s
            + self.comm_s
            + self.straggler_wait_s
            + self.restart_s
            + self.capacity_wait_s
    }

    /// Total of all components including the residual — bitwise equal to
    /// the `duration_s` this attribution was computed from.
    pub fn total_s(&self) -> f64 {
        self.partial() + self.unattributed_s
    }
}

/// Dollar decomposition of one job's bill; `total()` reproduces the
/// job's `total_cost()` bit-exactly.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct CostAttribution {
    /// optimizer probe spend (serverless probe fleets / VM trial fleets)
    pub profiling: f64,
    /// training execution spend (lambda + VM, minus probes and the
    /// straggler premium)
    pub compute: f64,
    /// billed straggler tails past each iteration's wall time
    /// (semi-sync: stragglers billed to their own completion)
    pub straggler_premium: f64,
    /// parameter-store traffic
    pub comm: f64,
    /// object-store requests
    pub storage: f64,
    /// residual: `total_cost - (sum of the above)`, exactly
    pub unattributed: f64,
}

impl CostAttribution {
    fn partial(&self) -> f64 {
        self.profiling + self.compute + self.straggler_premium + self.comm + self.storage
    }

    /// Total including the residual — bitwise equal to the job's
    /// `total_cost()`.
    pub fn total(&self) -> f64 {
        self.partial() + self.unattributed
    }
}

/// One job's complete attribution.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct JobAttribution {
    pub tenant: TenantId,
    pub time: TimeAttribution,
    pub cost: CostAttribution,
}

fn attribute_parts(
    trace: &TraceLog,
    duration_s: f64,
    ledger: &CostLedger,
    pricing: &Pricing,
    total_cost: f64,
) -> (TimeAttribution, CostAttribution) {
    let mut time = TimeAttribution {
        queueing_s: trace.bucket_sum_s(TimeBucket::Queueing),
        idle_s: trace.bucket_sum_s(TimeBucket::Idle),
        profiling_s: trace.bucket_sum_s(TimeBucket::Profiling),
        init_s: trace.bucket_sum_s(TimeBucket::Init),
        compute_s: trace.bucket_sum_s(TimeBucket::Compute),
        bubble_s: trace.bucket_sum_s(TimeBucket::Bubble),
        comm_s: trace.bucket_sum_s(TimeBucket::Comm),
        straggler_wait_s: trace.bucket_sum_s(TimeBucket::StragglerWait),
        restart_s: trace.bucket_sum_s(TimeBucket::Restart),
        capacity_wait_s: trace.bucket_sum_s(TimeBucket::CapacityWait),
        unattributed_s: 0.0,
    };
    time.unattributed_s = duration_s - time.partial();

    // probe spend and straggler premiums ride the trace (the ledger
    // aggregates them into lambda_compute / vm); everything else comes
    // from the ledger's own categories
    let mut profiling = 0.0f64;
    let mut premium = 0.0f64;
    for e in &trace.events {
        match e.kind {
            EventKind::Probe { cost, .. } => profiling += cost,
            EventKind::StragglerWait { premium_cost } => premium += cost_nonnan(premium_cost),
            _ => {}
        }
    }
    let mut cost = CostAttribution {
        profiling,
        compute: (ledger.lambda_compute + ledger.vm) - profiling - premium,
        straggler_premium: premium,
        comm: ledger.param_store,
        storage: ledger.s3_cost(pricing),
        unattributed: 0.0,
    };
    cost.unattributed = total_cost - cost.partial();
    (time, cost)
}

/// NaN guard for payload sums: a NaN premium would poison the whole
/// decomposition; treat it as zero and let the residual absorb it.
fn cost_nonnan(x: f64) -> f64 {
    if x.is_nan() {
        0.0
    } else {
        x
    }
}

/// Attribute one fleet job: its trace spans against
/// `duration_s() = finish_s - arrive_s`, its ledger against
/// `outcome.total_cost()`.
pub fn attribute_job(j: &JobOutcome) -> JobAttribution {
    let (time, cost) = attribute_parts(
        &j.outcome.trace,
        j.duration_s(),
        &j.outcome.ledger,
        &j.outcome.pricing,
        j.outcome.total_cost(),
    );
    JobAttribution { tenant: j.tenant, time, cost }
}

/// Attribute a single-tenant run (`simulate` / `simulate_traced`): the
/// job arrives at t = 0, so its duration is `total_time_s`.
pub fn attribute_sim(out: &SimOutcome) -> JobAttribution {
    let (time, cost) = attribute_parts(
        &out.trace,
        out.total_time_s,
        &out.ledger,
        &out.pricing,
        out.total_cost(),
    );
    JobAttribution { tenant: 0, time, cost }
}

/// Attribute every job of a fleet run, in `jobs` order.
pub fn attribute_fleet(out: &FleetOutcome) -> Vec<JobAttribution> {
    out.jobs.iter().map(attribute_job).collect()
}

/// Reconstruct the fleet's billed grand total from per-job attributions
/// plus the shared warm-pool cost — the same left fold as
/// [`FleetOutcome::total_cost`], so when each job's `cost.total()`
/// reproduces its bill exactly, this reproduces the fleet total (and the
/// [`BillingReport`](crate::metrics::BillingReport) grand total pinned
/// to it) exactly too.
pub fn attributed_fleet_cost(atts: &[JobAttribution], warm_cost: f64) -> f64 {
    atts.iter().map(|a| a.cost.total()).sum::<f64>() + warm_cost
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::SystemKind;
    use crate::coordinator::simrun::{simulate, simulate_traced, SimJob};
    use crate::coordinator::Workloads;
    use crate::perfmodel::ModelProfile;

    fn quick_job(system: SystemKind) -> SimJob {
        let phases = Workloads::static_run(ModelProfile::bert_small(), 60, 256);
        SimJob::new(system, phases)
    }

    #[test]
    fn traced_single_job_attribution_is_bit_exact() {
        let job = quick_job(SystemKind::Smlt);
        let out = simulate_traced(&job);
        assert!(!out.trace.is_empty(), "traced run must record events");
        let att = attribute_sim(&out);
        assert_eq!(
            att.time.total_s().to_bits(),
            out.total_time_s.to_bits(),
            "time components + residual must reproduce the duration exactly"
        );
        assert_eq!(
            att.cost.total().to_bits(),
            out.total_cost().to_bits(),
            "cost components + residual must reproduce the bill exactly"
        );
        // the leaf spans cover the whole run: the residual is float
        // noise, not a missing category
        assert!(
            att.time.unattributed_s.abs() < 1e-6 * out.total_time_s.max(1.0),
            "unattributed {} vs duration {}",
            att.time.unattributed_s,
            out.total_time_s
        );
        assert!(att.time.compute_s > 0.0);
        assert!(att.time.profiling_s > 0.0, "SMLT profiles its initial config");
        assert!(att.cost.compute > 0.0);
    }

    #[test]
    fn untraced_run_attributes_everything_to_the_residual() {
        let job = quick_job(SystemKind::Smlt);
        let out = simulate(&job);
        assert!(out.trace.is_empty());
        let att = attribute_sim(&out);
        assert_eq!(att.time.partial(), 0.0);
        assert_eq!(att.time.unattributed_s.to_bits(), out.total_time_s.to_bits());
        assert_eq!(att.time.total_s().to_bits(), out.total_time_s.to_bits());
        assert_eq!(att.cost.total().to_bits(), out.total_cost().to_bits());
    }

    #[test]
    fn tracing_never_changes_the_outcome() {
        for sys in [SystemKind::Smlt, SystemKind::Mlcd] {
            let job = quick_job(sys);
            let a = simulate(&job);
            let b = simulate_traced(&job);
            assert_eq!(a.total_time_s.to_bits(), b.total_time_s.to_bits());
            assert_eq!(a.total_cost().to_bits(), b.total_cost().to_bits());
            assert_eq!(a.iters_done, b.iters_done);
        }
    }
}
